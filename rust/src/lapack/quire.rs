//! Quire-exact panel factorizations and solves — the LAPACK layer of
//! `accum=quire` jobs.
//!
//! The rounded panels (`getf2`/`potf2`) round after every
//! multiply-accumulate; the routines here restructure the same
//! eliminations into left-looking (Crout) sweeps where each stored entry
//! is ONE fused dot product — all partial products accumulate exactly in
//! the format's quire ([`Scalar::QuireAcc`]) and round once, followed by
//! at most one divide or square-root rounding. The factors therefore
//! differ (deliberately) from the rounded path: this is the accumulation
//! mode the paper's hardware could not measure. Oracle-exactness is
//! pinned at the dot-product primitive by the exhaustive Posit(8,2)
//! sweep (`tests/quire_exhaustive.rs`); job-level determinism across
//! worker counts by `tests/service_determinism.rs`.

use super::getrf::laswp;
use super::LapackError;
use crate::blas::{trsm_quire, Diag, Scalar, Side, Trans, Uplo};

/// Quire-exact unblocked LU with partial pivoting on an m×n panel:
/// Crout/left-looking, so every `L\U` entry is one fused dot product
/// (plus one divide rounding below the diagonal). Pivots are chosen on
/// the fused-dot column values — the quire analog of `getf2`'s search.
/// Same contract as [`super::getf2`]: `ipiv` records panel-relative
/// swaps, a zero pivot is recorded and skipped, and the first singular
/// column is reported.
pub fn getf2_quire<T: Scalar>(
    m: usize,
    n: usize,
    a: &mut [T],
    lda: usize,
    ipiv: &mut [usize],
) -> Result<(), LapackError> {
    debug_assert!(lda >= m.max(1), "getf2_quire: lda {lda} < m {m}");
    debug_assert!(
        m == 0 || n == 0 || a.len() >= lda * (n - 1) + m,
        "getf2_quire: buffer len {} too small for {m}x{n} at lda {lda}",
        a.len()
    );
    debug_assert!(ipiv.len() >= n.min(m), "getf2_quire: ipiv len {}", ipiv.len());
    let mut first_singular: Option<usize> = None;
    for j in 0..n {
        // Column j, fused: rows above the diagonal become U entries
        // (dot against their own L row), rows at/below become the
        // pre-division pivot candidates (dot against the full L row so
        // far). Each is exactly one quire_finish rounding.
        for i in 0..m {
            let lim = i.min(j);
            if lim == 0 {
                continue; // nothing to subtract yet
            }
            let mut q = T::quire_zero();
            T::quire_add(&mut q, a[i + j * lda]);
            for l in 0..lim {
                T::quire_mac_sub(&mut q, a[i + l * lda], a[l + j * lda]);
            }
            a[i + j * lda] = T::quire_finish(q);
        }
        if j >= m {
            continue;
        }
        // Pivot search on the fused column values (exact comparison).
        let mut p = j;
        for i in j + 1..m {
            if a[i + j * lda].abs_gt(a[p + j * lda]) {
                p = i;
            }
        }
        ipiv[j] = p;
        if a[p + j * lda].is_zero() {
            first_singular.get_or_insert(j + 1);
            continue;
        }
        if p != j {
            crate::blas::swap_rows(a, lda, n, j, p);
        }
        // Divide the column below the pivot: one rounding each.
        let piv = a[j + j * lda];
        for i in j + 1..m {
            a[i + j * lda] = a[i + j * lda].div(piv);
        }
    }
    match first_singular {
        Some(i) => Err(LapackError::SingularU(i)),
        None => Ok(()),
    }
}

/// Quire-exact unblocked lower Cholesky: left-looking, each `L` entry is
/// one fused dot product plus one sqrt (diagonal) or divide (below)
/// rounding. Same error contract as [`super::potf2`] (`BadValue` /
/// `NotPositiveDefinite` with 1-based index); the upper triangle is
/// never touched.
pub fn potf2_quire<T: Scalar>(n: usize, a: &mut [T], lda: usize) -> Result<(), LapackError> {
    debug_assert!(lda >= n.max(1), "potf2_quire: lda {lda} < n {n}");
    debug_assert!(
        n == 0 || a.len() >= lda * (n - 1) + n,
        "potf2_quire: buffer len {} too small for {n}x{n} at lda {lda}",
        a.len()
    );
    for j in 0..n {
        // d = a(j,j) - Σ_{l<j} l(j,l)², fused: one rounding before sqrt.
        let mut q = T::quire_zero();
        T::quire_add(&mut q, a[j + j * lda]);
        for l in 0..j {
            let v = a[j + l * lda];
            T::quire_mac_sub(&mut q, v, v);
        }
        let d = T::quire_finish(q);
        if d.is_bad() {
            return Err(LapackError::BadValue(j + 1));
        }
        if d.to_f64() <= 0.0 {
            return Err(LapackError::NotPositiveDefinite(j + 1));
        }
        let ljj = d.sqrt();
        a[j + j * lda] = ljj;
        // l(i,j) = fused(a(i,j) - Σ_{l<j} l(i,l) l(j,l)) / l(j,j).
        for i in j + 1..n {
            let mut q = T::quire_zero();
            T::quire_add(&mut q, a[i + j * lda]);
            for l in 0..j {
                T::quire_mac_sub(&mut q, a[i + l * lda], a[j + l * lda]);
            }
            a[i + j * lda] = T::quire_finish(q).div(ljj);
        }
    }
    Ok(())
}

/// Quire-exact `getrs` (no-transpose): both substitution sweeps run as
/// fused dots via [`trsm_quire`]. Solves `A X = B` from a factorization
/// produced by [`getf2_quire`] (or any L\U + ipiv in the same layout).
pub fn getrs_quire<T: Scalar>(
    n: usize,
    nrhs: usize,
    lu: &[T],
    lda: usize,
    ipiv: &[usize],
    b: &mut [T],
    ldb: usize,
) {
    laswp(nrhs, b, ldb, 0, n, ipiv);
    trsm_quire(Side::Left, Uplo::Lower, Trans::No, Diag::Unit, n, nrhs, lu, lda, b, ldb);
    trsm_quire(Side::Left, Uplo::Upper, Trans::No, Diag::NonUnit, n, nrhs, lu, lda, b, ldb);
}

/// Quire-exact `potrs`: `X = L^{-T} L^{-1} B` with fused substitutions.
pub fn potrs_quire<T: Scalar>(
    n: usize,
    nrhs: usize,
    l: &[T],
    lda: usize,
    b: &mut [T],
    ldb: usize,
) {
    trsm_quire(Side::Left, Uplo::Lower, Trans::No, Diag::NonUnit, n, nrhs, l, lda, b, ldb);
    trsm_quire(Side::Left, Uplo::Lower, Trans::Yes, Diag::NonUnit, n, nrhs, l, lda, b, ldb);
}

#[cfg(test)]
mod tests {
    use super::super::{backward_error, getf2, potf2};
    use super::*;
    use crate::blas::{gemm, Matrix};
    use crate::posit::Posit32;
    use crate::rng::Pcg64;

    fn spd(n: usize, seed: u64) -> Matrix<f64> {
        let mut rng = Pcg64::seed(seed);
        let x = Matrix::<f64>::random_normal(n, n, 1.0, &mut rng);
        let mut a = Matrix::<f64>::zeros(n, n);
        gemm(
            Trans::Yes, Trans::No, n, n, n, 1.0, &x.data, n, &x.data, n, 0.0, &mut a.data, n,
        );
        for i in 0..n {
            a[(i, i)] += n as f64 * 0.1;
        }
        a
    }

    #[test]
    fn quire_lu_solves_no_worse_than_rounded() {
        let n = 40;
        let mut rng = Pcg64::seed(400);
        let a64 = Matrix::<f64>::random_normal(n, n, 1.0, &mut rng);
        let xsol = vec![1.0 / (n as f64).sqrt(); n];
        let mut b64 = vec![0.0f64; n];
        gemm(
            Trans::No, Trans::No, n, 1, n, 1.0, &a64.data, n, &xsol, n, 0.0, &mut b64, n,
        );
        let a: Matrix<Posit32> = a64.cast();
        let bp: Vec<Posit32> = b64.iter().map(|&v| Posit32::from_f64(v)).collect();

        let mut luq = a.clone();
        let mut pq = vec![0usize; n];
        getf2_quire(n, n, &mut luq.data, n, &mut pq).unwrap();
        let mut xq = bp.clone();
        getrs_quire(n, 1, &luq.data, n, &pq, &mut xq, n);

        let mut lur = a.clone();
        let mut pr = vec![0usize; n];
        getf2(n, n, &mut lur.data, n, &mut pr).unwrap();
        let mut xr = bp.clone();
        crate::lapack::getrs(n, 1, &lur.data, n, &pr, &mut xr, n);

        let eq = backward_error(&a64, &b64, &xq);
        let er = backward_error(&a64, &b64, &xr);
        assert!(eq.is_finite() && eq > 0.0);
        assert!(eq <= er * 1.5, "quire berr {eq:.3e} vs rounded {er:.3e}");
    }

    #[test]
    fn quire_cholesky_solves_no_worse_than_rounded() {
        let n = 32;
        let a64 = spd(n, 401);
        let xsol = vec![1.0 / (n as f64).sqrt(); n];
        let mut b64 = vec![0.0f64; n];
        gemm(
            Trans::No, Trans::No, n, 1, n, 1.0, &a64.data, n, &xsol, n, 0.0, &mut b64, n,
        );
        let a: Matrix<Posit32> = a64.cast();
        let bp: Vec<Posit32> = b64.iter().map(|&v| Posit32::from_f64(v)).collect();

        let mut lq = a.clone();
        potf2_quire(n, &mut lq.data, n).unwrap();
        let mut xq = bp.clone();
        potrs_quire(n, 1, &lq.data, n, &mut xq, n);

        let mut lr = a.clone();
        potf2(n, &mut lr.data, n).unwrap();
        let mut xr = bp.clone();
        crate::lapack::potrs(n, 1, &lr.data, n, &mut xr, n);

        let eq = backward_error(&a64, &b64, &xq);
        let er = backward_error(&a64, &b64, &xr);
        assert!(eq.is_finite() && eq > 0.0);
        assert!(eq <= er * 1.5, "quire berr {eq:.3e} vs rounded {er:.3e}");
    }

    #[test]
    fn quire_cholesky_factor_reconstructs() {
        // L·Lᵀ must reproduce A to format accuracy (validity, not just
        // relative comparison).
        let n = 20;
        let a64 = spd(n, 402);
        let a: Matrix<Posit32> = a64.cast();
        let mut l = a.clone();
        potf2_quire(n, &mut l.data, n).unwrap();
        let mut lf = Matrix::<f64>::zeros(n, n);
        for j in 0..n {
            for i in j..n {
                lf[(i, j)] = l[(i, j)].to_f64();
            }
        }
        let mut llt = Matrix::<f64>::zeros(n, n);
        gemm(
            Trans::No, Trans::Yes, n, n, n, 1.0, &lf.data, n, &lf.data, n, 0.0, &mut llt.data, n,
        );
        let scale = a64.data.iter().fold(0.0f64, |m, &v| m.max(v.abs()));
        assert!(llt.max_abs_diff(&a64) < 1e-5 * scale, "LLᵀ far from A");
    }

    #[test]
    fn quire_lu_rejects_singular() {
        let n = 4;
        let mut a = Matrix::<f64>::from_fn(n, n, |i, j| ((i + 1) * (j + 1)) as f64);
        let mut ipiv = vec![0usize; n];
        let err = getf2_quire(n, n, &mut a.data, n, &mut ipiv).unwrap_err();
        assert!(matches!(err, LapackError::SingularU(_)));
        let mut bad = Matrix::<f64>::identity(3);
        bad[(2, 2)] = -1.0;
        let err = potf2_quire(3, &mut bad.data, 3).unwrap_err();
        assert_eq!(err, LapackError::NotPositiveDefinite(3));
    }
}
