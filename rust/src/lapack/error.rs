//! Backward-error evaluation — the paper's Eq. (4)–(5) (§5.1).
//!
//! The paper measures `e = |b - A x̂| / |b|` (2-norms, computed in
//! binary64) where `b = A x_sol` is built in binary64 from the true
//! solution `x_sol = (1/√N, ..., 1/√N)`, and reports
//! `log10(e_binary32 / e_posit)` — positive when Posit(32,2) is more
//! accurate, in decimal digits.

use crate::blas::{gemm, Matrix, Scalar, Trans};

/// Relative backward error `|b - A x̂|₂ / |b|₂`, evaluated in f64.
/// `a` and `b` are the *binary64* problem data; `x_hat` is the computed
/// solution in any format (converted exactly to f64).
pub fn backward_error<T: Scalar>(a: &Matrix<f64>, b: &[f64], x_hat: &[T]) -> f64 {
    let n = a.rows;
    assert_eq!(a.cols, n);
    assert_eq!(b.len(), n);
    assert_eq!(x_hat.len(), n);
    let xf: Vec<f64> = x_hat.iter().map(|&v| v.to_f64()).collect();
    let mut r = b.to_vec();
    // r = b - A x̂ in f64.
    gemm(
        Trans::No,
        Trans::No,
        n,
        1,
        n,
        -1.0,
        &a.data,
        n,
        &xf,
        n,
        1.0,
        &mut r,
        n,
    );
    norm2(&r) / norm2(b)
}

/// Relative forward error `|x̂ - x_sol|₂ / |x_sol|₂` in f64.
pub fn forward_error<T: Scalar>(x_sol: &[f64], x_hat: &[T]) -> f64 {
    let diff2: f64 = x_sol
        .iter()
        .zip(x_hat)
        .map(|(&s, &h)| {
            let d = h.to_f64() - s;
            d * d
        })
        .sum();
    diff2.sqrt() / norm2(x_sol)
}

/// Residual of a solve in f64: convenience wrapper returning both errors.
pub fn solve_residual_f64<T: Scalar>(
    a: &Matrix<f64>,
    b: &[f64],
    x_sol: &[f64],
    x_hat: &[T],
) -> (f64, f64) {
    (backward_error(a, b, x_hat), forward_error(x_sol, x_hat))
}

fn norm2(v: &[f64]) -> f64 {
    v.iter().map(|&x| x * x).sum::<f64>().sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn exact_solution_has_zero_error() {
        let n = 8;
        let mut rng = Pcg64::seed(1);
        let a = Matrix::<f64>::random_normal(n, n, 1.0, &mut rng);
        let x = vec![1.0; n];
        let mut b = vec![0.0; n];
        gemm(
            Trans::No, Trans::No, n, 1, n, 1.0, &a.data, n, &x, n, 0.0, &mut b,
            n,
        );
        assert_eq!(backward_error(&a, &b, &x), 0.0);
        assert_eq!(forward_error(&x, &x), 0.0);
    }

    #[test]
    fn perturbed_solution_scales() {
        let n = 4;
        let a = Matrix::<f64>::identity(n);
        let b = vec![1.0; n];
        let x_hat = vec![1.0 + 1e-6, 1.0, 1.0, 1.0];
        let e = backward_error(&a, &b, &x_hat);
        assert!((e - 1e-6 / 2.0).abs() < 1e-9); // |r|=1e-6, |b|=2
    }
}
