//! LAPACK-style dense factorizations, generic over [`crate::blas::Scalar`]
//! — the MPLAPACK `Rgetrf` / `Rpotrf` / `Rgetrs` / `Rpotrs` routines the
//! paper ports to Posit(32,2) (§3), plus the backward-error evaluation of
//! its Eq. (4)–(5).
//!
//! The blocked algorithms follow LAPACK exactly (right-looking, Level-3
//! updates), so the trailing-matrix GEMM — the paper's offload target — is
//! the dominant cost. `coordinator::drivers` re-implements the same loops
//! with the GEMM dispatched to an accelerator backend; both must agree
//! bit-for-bit with the all-native versions here (integration-tested).

mod error;
mod getrf;
mod potrf;
mod quire;
mod refine;
mod scale;
mod solve;

pub use error::{backward_error, forward_error, solve_residual_f64};
pub use refine::{gesv_refine, RefineResult};
pub use scale::{equilibrate_pow2, gesv_scaled, Equilibration};
pub use getrf::{getf2, getf2_ref, getf2_unpacked, getrf, getrf_ref, laswp};
pub use potrf::{potf2, potf2_ref, potrf, potrf_ref};
pub use quire::{getf2_quire, getrs_quire, potf2_quire, potrs_quire};
pub use solve::{getrs, potrs};

/// Failure modes of the factorizations (LAPACK `info` codes, typed).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LapackError {
    /// `getrf`: U(i,i) is exactly zero; factorization finished but U is
    /// singular (1-based index like LAPACK's `info`).
    SingularU(usize),
    /// `potrf`: leading minor of order i is not positive definite.
    NotPositiveDefinite(usize),
    /// A NaR/NaN/Inf appeared during factorization.
    BadValue(usize),
}

impl core::fmt::Display for LapackError {
    fn fmt(&self, f: &mut core::fmt::Formatter<'_>) -> core::fmt::Result {
        match self {
            LapackError::SingularU(i) => write!(f, "singular: U({i},{i}) == 0"),
            LapackError::NotPositiveDefinite(i) => {
                write!(f, "leading minor {i} not positive definite")
            }
            LapackError::BadValue(i) => write!(f, "non-finite value at step {i}"),
        }
    }
}
impl std::error::Error for LapackError {}

/// Default LAPACK-style block size for the right-looking algorithms. The
/// paper's FPGA analysis (Fig 6) shows trailing updates with K = 32..256;
/// 64 balances panel (CPU) vs update (accelerator) cost on this testbed.
pub const DEFAULT_NB: usize = 64;
