//! Triangular solvers on factorized matrices: `Rgetrs` / `Rpotrs` —
//! the routines the paper uses to turn factorizations into linear-system
//! solutions for the error study (§5.1).

use super::getrf::laswp;
use crate::blas::{trsm, Diag, Scalar, Side, Trans, Uplo};

/// Solve `A X = B` given the LU factorization from `getrf` (`getrs`,
/// no-transpose case). `b` is n×nrhs, overwritten with X.
pub fn getrs<T: Scalar>(
    n: usize,
    nrhs: usize,
    lu: &[T],
    lda: usize,
    ipiv: &[usize],
    b: &mut [T],
    ldb: usize,
) {
    // X = U^{-1} L^{-1} P B.
    laswp(nrhs, b, ldb, 0, n, ipiv);
    trsm(
        Side::Left,
        Uplo::Lower,
        Trans::No,
        Diag::Unit,
        n,
        nrhs,
        T::one(),
        lu,
        lda,
        b,
        ldb,
    );
    trsm(
        Side::Left,
        Uplo::Upper,
        Trans::No,
        Diag::NonUnit,
        n,
        nrhs,
        T::one(),
        lu,
        lda,
        b,
        ldb,
    );
}

/// Solve `A X = B` given the lower Cholesky factor from `potrf` (`potrs`).
pub fn potrs<T: Scalar>(
    n: usize,
    nrhs: usize,
    l: &[T],
    lda: usize,
    b: &mut [T],
    ldb: usize,
) {
    // X = L^{-T} L^{-1} B.
    trsm(
        Side::Left,
        Uplo::Lower,
        Trans::No,
        Diag::NonUnit,
        n,
        nrhs,
        T::one(),
        l,
        lda,
        b,
        ldb,
    );
    trsm(
        Side::Left,
        Uplo::Lower,
        Trans::Yes,
        Diag::NonUnit,
        n,
        nrhs,
        T::one(),
        l,
        lda,
        b,
        ldb,
    );
}

#[cfg(test)]
mod tests {
    use super::super::{getrf, potrf};
    use super::*;
    use crate::blas::{gemm, Matrix};
    use crate::posit::Posit32;
    use crate::rng::Pcg64;

    #[test]
    fn lu_solve_f64_roundtrip() {
        let n = 30;
        let mut rng = Pcg64::seed(300);
        let a0 = Matrix::<f64>::random_normal(n, n, 1.0, &mut rng);
        let xsol = vec![1.0 / (n as f64).sqrt(); n];
        let mut b = vec![0.0f64; n];
        gemm(
            Trans::No, Trans::No, n, 1, n, 1.0, &a0.data, n, &xsol, n, 0.0,
            &mut b, n,
        );
        let mut lu = a0.clone();
        let mut ipiv = vec![0usize; n];
        getrf(n, n, &mut lu.data, n, &mut ipiv, 8, 1).unwrap();
        getrs(n, 1, &lu.data, n, &ipiv, &mut b, n);
        for i in 0..n {
            assert!((b[i] - xsol[i]).abs() < 1e-10, "x[{i}]");
        }
    }

    #[test]
    fn cholesky_solve_posit_close_to_solution() {
        let n = 24;
        let mut rng = Pcg64::seed(301);
        let x = Matrix::<f64>::random_normal(n, n, 1.0, &mut rng);
        let mut a0 = Matrix::<f64>::zeros(n, n);
        gemm(
            Trans::Yes, Trans::No, n, n, n, 1.0, &x.data, n, &x.data, n, 0.0,
            &mut a0.data, n,
        );
        for i in 0..n {
            a0[(i, i)] += n as f64 * 0.1;
        }
        let xsol = vec![1.0 / (n as f64).sqrt(); n];
        let mut bf = vec![0.0f64; n];
        gemm(
            Trans::No, Trans::No, n, 1, n, 1.0, &a0.data, n, &xsol, n, 0.0,
            &mut bf, n,
        );
        let ap: Matrix<Posit32> = a0.cast();
        let mut l = ap.clone();
        potrf(n, &mut l.data, n, 8).unwrap();
        let mut bp: Vec<Posit32> = bf.iter().map(|&v| Posit32::from_f64(v)).collect();
        potrs(n, 1, &l.data, n, &mut bp, n);
        for i in 0..n {
            let err = (bp[i].to_f64() - xsol[i]).abs();
            assert!(err < 1e-4, "x[{i}] err {err}");
        }
    }
}
