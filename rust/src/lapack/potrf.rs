//! Cholesky factorization (`Rpotrf` / LAPACK `dpotrf`), lower variant:
//! `A = L * L^T` for symmetric positive definite A. Right-looking blocked
//! algorithm; the trailing SYRK/GEMM update is the paper's offload target.
//!
//! §Perf (decode-once factorization pipeline): [`potf2`] decodes the
//! block's lower triangle **once**, runs the whole sweep — dot-product
//! subtractions, the positive-definite pivot checks, square roots and
//! column scalings — in the decoded domain, and encodes back once per
//! element. Same rounding points as the scalar reference [`potf2_ref`]
//! (one per multiply/subtract/divide/sqrt), identical error behaviour
//! including the partially-updated state a failed sweep leaves behind —
//! bit-identity pinned by the tests and
//! `rust/tests/factor_packed.rs`.

use super::LapackError;
use crate::blas::{syrk_lower, trsm, trsm_ref, Diag, Scalar, Side, Trans, Uplo};

/// Unblocked lower Cholesky (LAPACK `potf2`) via the decode-once panel
/// sweep. Overwrites the lower triangle of the leading n×n block of `a`;
/// upper triangle untouched. Bit-identical to [`potf2_ref`], including
/// the partial state left by a failed sweep.
pub fn potf2<T: Scalar>(n: usize, a: &mut [T], lda: usize) -> Result<(), LapackError> {
    debug_assert!(lda >= n.max(1), "potf2: lda {lda} < n {n}");
    debug_assert!(
        n == 0 || a.len() >= lda * (n - 1) + n,
        "potf2: buffer len {} too small for {n}x{n} at lda {lda}",
        a.len()
    );
    if n == 0 {
        return Ok(());
    }
    // Decode the lower triangle once (the upper is never read or written).
    let mut w: Vec<T::Unpacked> = vec![T::unpacked_pad(); n * n];
    for j in 0..n {
        for i in j..n {
            w[i + j * n] = a[i + j * lda].unpack();
        }
    }
    let mut result = Ok(());
    for j in 0..n {
        // d = a(j,j) - sum_{l<j} a(j,l)^2, sequentially rounded (the
        // exact negation folded into the multiplicand).
        let mut d = T::uacc_load(w[j + j * n]);
        for l in 0..j {
            let v = w[j + l * n];
            d = T::uacc_mac(d, T::unpacked_neg(v), v);
        }
        if T::uacc_is_bad(d) {
            result = Err(LapackError::BadValue(j + 1));
            break;
        }
        // Positive-definite check: the paper's Rpotrf fails the same way
        // LAPACK does (info = j+1) when the pivot is not positive — an
        // exact sign test on the decoded planes.
        if T::uacc_le_zero(d) {
            result = Err(LapackError::NotPositiveDefinite(j + 1));
            break;
        }
        let ljj = T::uacc_store(T::uacc_sqrt(d));
        w[j + j * n] = ljj;
        // Column below: a(i,j) = (a(i,j) - sum_{l<j} a(i,l) a(j,l)) / ljj.
        for i in j + 1..n {
            let mut s = T::uacc_load(w[i + j * n]);
            for l in 0..j {
                s = T::uacc_mac(s, T::unpacked_neg(w[i + l * n]), w[j + l * n]);
            }
            w[i + j * n] = T::uacc_store(T::uacc_div(s, ljj));
        }
    }
    // Encode the lower triangle back once per element. On error this
    // reproduces the scalar reference's partial state exactly: columns
    // before the failing one are updated, the rest round-trip unchanged.
    for j in 0..n {
        for i in j..n {
            a[i + j * lda] = T::unpacked_encode(w[i + j * n]);
        }
    }
    result
}

/// The scalar reference `potf2`, retained as the bit-identity ground
/// truth and the factorization bench baseline.
pub fn potf2_ref<T: Scalar>(n: usize, a: &mut [T], lda: usize) -> Result<(), LapackError> {
    for j in 0..n {
        // d = a(j,j) - sum_{l<j} a(j,l)^2, sequentially rounded.
        let mut d = a[j + j * lda];
        for l in 0..j {
            let v = a[j + l * lda];
            d = d.sub(v.mul(v));
        }
        if d.is_bad() {
            return Err(LapackError::BadValue(j + 1));
        }
        // Positive-definite check: the paper's Rpotrf fails the same way
        // LAPACK does (info = j+1) when the pivot is not positive. The
        // f64 view is exact for all supported formats, so this is an
        // exact sign test.
        if d.to_f64() <= 0.0 {
            return Err(LapackError::NotPositiveDefinite(j + 1));
        }
        let ljj = d.sqrt();
        a[j + j * lda] = ljj;
        // Column below: a(i,j) = (a(i,j) - sum_{l<j} a(i,l) a(j,l)) / ljj.
        for i in j + 1..n {
            let mut s = a[i + j * lda];
            for l in 0..j {
                s = s.sub(a[i + l * lda].mul(a[j + l * lda]));
            }
            a[i + j * lda] = s.div(ljj);
        }
    }
    Ok(())
}

/// Blocked right-looking lower Cholesky (LAPACK `potrf`).
///
/// Per block: `potf2` on the diagonal block (host), TRSM of the panel
/// below it, then the rank-nb SYRK trailing update (offloaded in the
/// coordinator variant).
pub fn potrf<T: Scalar>(
    n: usize,
    a: &mut [T],
    lda: usize,
    nb: usize,
) -> Result<(), LapackError> {
    if nb <= 1 || nb >= n {
        return potf2(n, a, lda);
    }
    let mut j = 0;
    while j < n {
        let jb = nb.min(n - j);
        // Diagonal block. potf2 uses only the block's own lower triangle,
        // which was fully updated by previous iterations' SYRK.
        {
            let diag = &mut a[j + j * lda..];
            potf2(jb, diag, lda).map_err(|e| match e {
                LapackError::NotPositiveDefinite(i) => {
                    LapackError::NotPositiveDefinite(i + j)
                }
                LapackError::BadValue(i) => LapackError::BadValue(i + j),
                other => other,
            })?;
        }
        if j + jb < n {
            // Panel: A21 = A21 * L11^{-T}.
            let m2 = n - j - jb;
            // L11 is read (rows j.., col j..j+jb); A21 written (rows
            // j+jb.., same columns). Disjoint rows, same columns — copy
            // L11's lower triangle (jb x jb) to break the overlap; it is
            // the small diagonal block, cheap.
            let mut l11 = vec![T::zero(); jb * jb];
            for c in 0..jb {
                let base = j + (j + c) * lda;
                l11[c * jb..(c + 1) * jb].copy_from_slice(&a[base..base + jb]);
            }
            let a21 = &mut a[(j + jb) + j * lda..];
            trsm(
                Side::Right,
                Uplo::Lower,
                Trans::Yes,
                Diag::NonUnit,
                m2,
                jb,
                T::one(),
                &l11,
                jb,
                a21,
                lda,
            );
            // Trailing update: A22 -= A21 * A21^T (lower triangle only).
            let mut a21_copy = vec![T::zero(); m2 * jb];
            for c in 0..jb {
                let base = (j + jb) + (j + c) * lda;
                a21_copy[c * m2..(c + 1) * m2].copy_from_slice(&a[base..base + m2]);
            }
            let a22 = &mut a[(j + jb) + (j + jb) * lda..];
            let minus_one = T::zero().sub(T::one());
            syrk_lower(m2, jb, minus_one, &a21_copy, m2, T::one(), a22, lda);
        }
        j += jb;
    }
    Ok(())
}

/// The pre-pipeline blocked Cholesky: scalar `potf2_ref` panels and
/// scalar `trsm_ref`, with the same SYRK trailing update. Retained as the
/// bit-identity ground truth and the `BENCH_factor.json` baseline.
pub fn potrf_ref<T: Scalar>(
    n: usize,
    a: &mut [T],
    lda: usize,
    nb: usize,
) -> Result<(), LapackError> {
    if nb <= 1 || nb >= n {
        return potf2_ref(n, a, lda);
    }
    let mut j = 0;
    while j < n {
        let jb = nb.min(n - j);
        {
            let diag = &mut a[j + j * lda..];
            potf2_ref(jb, diag, lda).map_err(|e| match e {
                LapackError::NotPositiveDefinite(i) => {
                    LapackError::NotPositiveDefinite(i + j)
                }
                LapackError::BadValue(i) => LapackError::BadValue(i + j),
                other => other,
            })?;
        }
        if j + jb < n {
            let m2 = n - j - jb;
            let mut l11 = vec![T::zero(); jb * jb];
            for c in 0..jb {
                let base = j + (j + c) * lda;
                l11[c * jb..(c + 1) * jb].copy_from_slice(&a[base..base + jb]);
            }
            let a21 = &mut a[(j + jb) + j * lda..];
            trsm_ref(
                Side::Right,
                Uplo::Lower,
                Trans::Yes,
                Diag::NonUnit,
                m2,
                jb,
                T::one(),
                &l11,
                jb,
                a21,
                lda,
            );
            let mut a21_copy = vec![T::zero(); m2 * jb];
            for c in 0..jb {
                let base = (j + jb) + (j + c) * lda;
                a21_copy[c * m2..(c + 1) * m2].copy_from_slice(&a[base..base + m2]);
            }
            let a22 = &mut a[(j + jb) + (j + jb) * lda..];
            let minus_one = T::zero().sub(T::one());
            syrk_lower(m2, jb, minus_one, &a21_copy, m2, T::one(), a22, lda);
        }
        j += jb;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::{gemm, Matrix, Trans};
    use crate::posit::Posit32;
    use crate::rng::Pcg64;

    /// SPD test matrix: A = X^T X + n*I computed in f64.
    fn spd(n: usize, sigma: f64, rng: &mut Pcg64) -> Matrix<f64> {
        let x = Matrix::<f64>::random_normal(n, n, sigma, rng);
        let mut a = Matrix::<f64>::identity(n);
        for v in a.data.iter_mut() {
            *v *= n as f64 * sigma * sigma * 0.01;
        }
        gemm(
            Trans::Yes, Trans::No, n, n, n, 1.0, &x.data, n, &x.data, n, 1.0,
            &mut a.data, n,
        );
        a
    }

    fn check_llt<T: crate::blas::Scalar>(a0: &Matrix<f64>, l: &Matrix<T>, tol: f64) {
        let n = a0.rows;
        let lf: Matrix<f64> = l.cast();
        let mut llt = Matrix::<f64>::zeros(n, n);
        // zero the upper triangle of L first
        let mut ltri = lf.clone();
        for j in 0..n {
            for i in 0..j {
                ltri[(i, j)] = 0.0;
            }
        }
        gemm(
            Trans::No, Trans::Yes, n, n, n, 1.0, &ltri.data, n, &ltri.data, n,
            0.0, &mut llt.data, n,
        );
        // Compare lower triangles (upper of A untouched by potrf).
        let mut err: f64 = 0.0;
        let mut scale: f64 = 0.0;
        for j in 0..n {
            for i in j..n {
                err = err.max((llt[(i, j)] - a0[(i, j)]).abs());
                scale = scale.max(a0[(i, j)].abs());
            }
        }
        assert!(err / scale < tol, "relative err {}", err / scale);
    }

    #[test]
    fn cholesky_reconstructs_f64() {
        let n = 40;
        let mut rng = Pcg64::seed(200);
        let a0 = spd(n, 1.0, &mut rng);
        let mut a = a0.clone();
        potrf(n, &mut a.data, n, 16).unwrap();
        check_llt(&a0, &a, 1e-12);
    }

    #[test]
    fn cholesky_posit_blocked_and_unblocked_agree_on_quality() {
        let n = 32;
        let mut rng = Pcg64::seed(201);
        let a0 = spd(n, 1.0, &mut rng);
        let ap: Matrix<Posit32> = a0.cast();
        let mut u = ap.clone();
        potf2(n, &mut u.data, n).unwrap();
        check_llt(&a0, &u, 1e-5);
        let mut b = ap.clone();
        potrf(n, &mut b.data, n, 8).unwrap();
        check_llt(&a0, &b, 1e-5);
    }

    #[test]
    fn decode_once_pipeline_matches_scalar_reference_bitwise() {
        // potf2 vs potf2_ref and potrf vs potrf_ref: identical factors on
        // SPD posit data, identical error + identical partial state on
        // indefinite data.
        let n = 20;
        let mut rng = Pcg64::seed(202);
        let a0 = spd(n, 1.0, &mut rng);
        let ap: Matrix<Posit32> = a0.cast();
        let mut u1 = ap.clone();
        let mut u2 = ap.clone();
        assert_eq!(potf2_ref(n, &mut u1.data, n), potf2(n, &mut u2.data, n));
        assert_eq!(u1.data, u2.data, "potf2 factors");
        let mut b1 = ap.clone();
        let mut b2 = ap.clone();
        assert_eq!(potrf_ref(n, &mut b1.data, n, 6), potrf(n, &mut b2.data, n, 6));
        assert_eq!(b1.data, b2.data, "potrf factors");

        // Indefinite: flip a diagonal entry mid-matrix; both paths must
        // fail at the same column with the same partially-updated matrix.
        let mut bad = ap.clone();
        bad[(n / 2, n / 2)] = Posit32::from_f64(-3.0);
        let mut c1 = bad.clone();
        let mut c2 = bad.clone();
        let e1 = potf2_ref(n, &mut c1.data, n).unwrap_err();
        let e2 = potf2(n, &mut c2.data, n).unwrap_err();
        assert_eq!(e1, e2);
        assert_eq!(c1.data, c2.data, "partial state after failure");
    }

    #[test]
    fn indefinite_matrix_fails_with_index() {
        let n = 5;
        let mut a = Matrix::<f64>::identity(n);
        a[(2, 2)] = -1.0; // third leading minor goes negative
        let err = potrf(n, &mut a.data, n, 2).unwrap_err();
        assert_eq!(err, LapackError::NotPositiveDefinite(3));
    }

    #[test]
    fn nar_input_fails_cleanly_posit() {
        let n = 4;
        let mut rng = Pcg64::seed(7);
        let a0 = spd(n, 1.0, &mut rng);
        let mut ap: Matrix<Posit32> = a0.cast();
        ap[(1, 1)] = Posit32::NAR;
        let err = potf2(n, &mut ap.data, n).unwrap_err();
        assert!(matches!(err, LapackError::BadValue(_)), "{err:?}");
    }
}
