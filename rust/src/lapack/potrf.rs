//! Cholesky factorization (`Rpotrf` / LAPACK `dpotrf`), lower variant:
//! `A = L * L^T` for symmetric positive definite A. Right-looking blocked
//! algorithm; the trailing SYRK/GEMM update is the paper's offload target.

use super::LapackError;
use crate::blas::{syrk_lower, trsm, Diag, Scalar, Side, Trans, Uplo};

/// Unblocked lower Cholesky (LAPACK `potf2`). Overwrites the lower
/// triangle of the leading n×n block of `a`; upper triangle untouched.
pub fn potf2<T: Scalar>(n: usize, a: &mut [T], lda: usize) -> Result<(), LapackError> {
    for j in 0..n {
        // d = a(j,j) - sum_{l<j} a(j,l)^2, sequentially rounded.
        let mut d = a[j + j * lda];
        for l in 0..j {
            let v = a[j + l * lda];
            d = d.sub(v.mul(v));
        }
        if d.is_bad() {
            return Err(LapackError::BadValue(j + 1));
        }
        // Positive-definite check: the paper's Rpotrf fails the same way
        // LAPACK does (info = j+1) when the pivot is not positive. The
        // f64 view is exact for all supported formats, so this is an
        // exact sign test.
        if d.to_f64() <= 0.0 {
            return Err(LapackError::NotPositiveDefinite(j + 1));
        }
        let ljj = d.sqrt();
        a[j + j * lda] = ljj;
        // Column below: a(i,j) = (a(i,j) - sum_{l<j} a(i,l) a(j,l)) / ljj.
        for i in j + 1..n {
            let mut s = a[i + j * lda];
            for l in 0..j {
                s = s.sub(a[i + l * lda].mul(a[j + l * lda]));
            }
            a[i + j * lda] = s.div(ljj);
        }
    }
    Ok(())
}

/// Blocked right-looking lower Cholesky (LAPACK `potrf`).
///
/// Per block: `potf2` on the diagonal block (host), TRSM of the panel
/// below it, then the rank-nb SYRK trailing update (offloaded in the
/// coordinator variant).
pub fn potrf<T: Scalar>(
    n: usize,
    a: &mut [T],
    lda: usize,
    nb: usize,
) -> Result<(), LapackError> {
    if nb <= 1 || nb >= n {
        return potf2(n, a, lda);
    }
    let mut j = 0;
    while j < n {
        let jb = nb.min(n - j);
        // Diagonal block. potf2 uses only the block's own lower triangle,
        // which was fully updated by previous iterations' SYRK.
        {
            let diag = &mut a[j + j * lda..];
            potf2(jb, diag, lda).map_err(|e| match e {
                LapackError::NotPositiveDefinite(i) => {
                    LapackError::NotPositiveDefinite(i + j)
                }
                LapackError::BadValue(i) => LapackError::BadValue(i + j),
                other => other,
            })?;
        }
        if j + jb < n {
            // Panel: A21 = A21 * L11^{-T}.
            let m2 = n - j - jb;
            // L11 is read (rows j.., col j..j+jb); A21 written (rows
            // j+jb.., same columns). Disjoint rows, same columns — copy
            // L11's lower triangle (jb x jb) to break the overlap; it is
            // the small diagonal block, cheap.
            let mut l11 = vec![T::zero(); jb * jb];
            for c in 0..jb {
                let base = j + (j + c) * lda;
                l11[c * jb..(c + 1) * jb].copy_from_slice(&a[base..base + jb]);
            }
            let a21 = &mut a[(j + jb) + j * lda..];
            trsm(
                Side::Right,
                Uplo::Lower,
                Trans::Yes,
                Diag::NonUnit,
                m2,
                jb,
                T::one(),
                &l11,
                jb,
                a21,
                lda,
            );
            // Trailing update: A22 -= A21 * A21^T (lower triangle only).
            let mut a21_copy = vec![T::zero(); m2 * jb];
            for c in 0..jb {
                let base = (j + jb) + (j + c) * lda;
                a21_copy[c * m2..(c + 1) * m2].copy_from_slice(&a[base..base + m2]);
            }
            let a22 = &mut a[(j + jb) + (j + jb) * lda..];
            let minus_one = T::zero().sub(T::one());
            syrk_lower(m2, jb, minus_one, &a21_copy, m2, T::one(), a22, lda);
        }
        j += jb;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::{gemm, Matrix, Trans};
    use crate::posit::Posit32;
    use crate::rng::Pcg64;

    /// SPD test matrix: A = X^T X + n*I computed in f64.
    fn spd(n: usize, sigma: f64, rng: &mut Pcg64) -> Matrix<f64> {
        let x = Matrix::<f64>::random_normal(n, n, sigma, rng);
        let mut a = Matrix::<f64>::identity(n);
        for v in a.data.iter_mut() {
            *v *= n as f64 * sigma * sigma * 0.01;
        }
        gemm(
            Trans::Yes, Trans::No, n, n, n, 1.0, &x.data, n, &x.data, n, 1.0,
            &mut a.data, n,
        );
        a
    }

    fn check_llt<T: crate::blas::Scalar>(a0: &Matrix<f64>, l: &Matrix<T>, tol: f64) {
        let n = a0.rows;
        let lf: Matrix<f64> = l.cast();
        let mut llt = Matrix::<f64>::zeros(n, n);
        // zero the upper triangle of L first
        let mut ltri = lf.clone();
        for j in 0..n {
            for i in 0..j {
                ltri[(i, j)] = 0.0;
            }
        }
        gemm(
            Trans::No, Trans::Yes, n, n, n, 1.0, &ltri.data, n, &ltri.data, n,
            0.0, &mut llt.data, n,
        );
        // Compare lower triangles (upper of A untouched by potrf).
        let mut err: f64 = 0.0;
        let mut scale: f64 = 0.0;
        for j in 0..n {
            for i in j..n {
                err = err.max((llt[(i, j)] - a0[(i, j)]).abs());
                scale = scale.max(a0[(i, j)].abs());
            }
        }
        assert!(err / scale < tol, "relative err {}", err / scale);
    }

    #[test]
    fn cholesky_reconstructs_f64() {
        let n = 40;
        let mut rng = Pcg64::seed(200);
        let a0 = spd(n, 1.0, &mut rng);
        let mut a = a0.clone();
        potrf(n, &mut a.data, n, 16).unwrap();
        check_llt(&a0, &a, 1e-12);
    }

    #[test]
    fn cholesky_posit_blocked_and_unblocked_agree_on_quality() {
        let n = 32;
        let mut rng = Pcg64::seed(201);
        let a0 = spd(n, 1.0, &mut rng);
        let ap: Matrix<Posit32> = a0.cast();
        let mut u = ap.clone();
        potf2(n, &mut u.data, n).unwrap();
        check_llt(&a0, &u, 1e-5);
        let mut b = ap.clone();
        potrf(n, &mut b.data, n, 8).unwrap();
        check_llt(&a0, &b, 1e-5);
    }

    #[test]
    fn indefinite_matrix_fails_with_index() {
        let n = 5;
        let mut a = Matrix::<f64>::identity(n);
        a[(2, 2)] = -1.0; // third leading minor goes negative
        let err = potrf(n, &mut a.data, n, 2).unwrap_err();
        assert_eq!(err, LapackError::NotPositiveDefinite(3));
    }

    #[test]
    fn nar_input_fails_cleanly_posit() {
        let n = 4;
        let mut rng = Pcg64::seed(7);
        let a0 = spd(n, 1.0, &mut rng);
        let mut ap: Matrix<Posit32> = a0.cast();
        ap[(1, 1)] = Posit32::NAR;
        let err = potf2(n, &mut ap.data, n).unwrap_err();
        assert!(matches!(err, LapackError::BadValue(_)), "{err:?}");
    }
}
