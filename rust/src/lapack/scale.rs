//! Golden-zone equilibration — the paper's own remedy, implemented.
//!
//! §5.1 (citing [2]): "scaling A and b by a factor that makes the
//! absolute values of the elements of A and b as close to 1 as possible
//! is effective to improve the accuracy of Posit(32,2) arithmetic."
//!
//! `equilibrate_pow2` computes row scales R and column scales C as
//! **powers of two**: the product has no significand error, so scaling
//! *toward* the golden zone is exact (the fraction field only widens as
//! |x| approaches 1 — tapered precision works in our favour), and only
//! the final unscale of x can round, once per element. `gesv_scaled`
//! solves `(R A C) y = R b, x = C y` with the standard posit LU.

use super::{getrf, getrs, LapackError};
use crate::blas::{Matrix, Scalar};

/// Row and column power-of-two scale exponents.
#[derive(Clone, Debug)]
pub struct Equilibration {
    /// Row i of A is multiplied by 2^row_exp[i].
    pub row_exp: Vec<i32>,
    /// Column j of A is multiplied by 2^col_exp[j].
    pub col_exp: Vec<i32>,
}

/// Compute power-of-two row/column scalings that bring each row's and
/// column's max magnitude near 1 (LAPACK `geequ` with exponents snapped
/// to powers of two). Operates on the exact f64 view.
pub fn equilibrate_pow2<T: Scalar>(a: &Matrix<T>) -> Equilibration {
    let (m, n) = (a.rows, a.cols);
    let mut row_exp = vec![0i32; m];
    for i in 0..m {
        let mut maxa: f64 = 0.0;
        for j in 0..n {
            maxa = maxa.max(a[(i, j)].to_f64().abs());
        }
        if maxa > 0.0 && maxa.is_finite() {
            row_exp[i] = -maxa.log2().round() as i32;
        }
    }
    let mut col_exp = vec![0i32; n];
    for j in 0..n {
        let mut maxa: f64 = 0.0;
        for i in 0..m {
            let v = a[(i, j)].to_f64().abs();
            maxa = maxa.max(v * (row_exp[i] as f64).exp2());
        }
        if maxa > 0.0 && maxa.is_finite() {
            col_exp[j] = -maxa.log2().round() as i32;
        }
    }
    Equilibration { row_exp, col_exp }
}

/// Scale a value by 2^e exactly (saturating at the format's range like
/// any posit op would).
fn scale_pow2<T: Scalar>(v: T, e: i32) -> T {
    if e == 0 {
        return v;
    }
    // Exact in both posit (regime shift) and IEEE (exponent shift) —
    // realized through from_f64/to_f64, both exact for our formats up to
    // the final single rounding, which only triggers on saturation.
    T::from_f64(v.to_f64() * (e as f64).exp2())
}

/// Solve `A x = b` with golden-zone pre-scaling (the paper's §5.1
/// recommendation): equilibrate, factorize the scaled matrix, solve,
/// unscale. Returns x in the original units.
pub fn gesv_scaled<T: Scalar>(
    a: &Matrix<T>,
    b: &[T],
    nb: usize,
    threads: usize,
) -> Result<Vec<T>, LapackError> {
    let n = a.rows;
    assert_eq!(a.cols, n);
    let eq = equilibrate_pow2(a);
    let mut sa = Matrix::<T>::zeros(n, n);
    for j in 0..n {
        for i in 0..n {
            sa[(i, j)] = scale_pow2(a[(i, j)], eq.row_exp[i] + eq.col_exp[j]);
        }
    }
    let mut sb: Vec<T> = b
        .iter()
        .enumerate()
        .map(|(i, &v)| scale_pow2(v, eq.row_exp[i]))
        .collect();
    let mut ipiv = vec![0usize; n];
    getrf(n, n, &mut sa.data, n, &mut ipiv, nb, threads)?;
    getrs(n, 1, &sa.data, n, &ipiv, &mut sb, n);
    // x = C y.
    for (j, x) in sb.iter_mut().enumerate() {
        *x = scale_pow2(*x, eq.col_exp[j]);
    }
    Ok(sb)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::{gemm, Trans};
    use crate::lapack::backward_error;
    use crate::posit::Posit32;
    use crate::rng::Pcg64;

    fn problem(n: usize, sigma: f64, seed: u64) -> (Matrix<f64>, Vec<f64>) {
        let mut rng = Pcg64::seed(seed);
        let a = Matrix::<f64>::random_normal(n, n, sigma, &mut rng);
        let xsol = vec![1.0 / (n as f64).sqrt(); n];
        let mut b = vec![0.0; n];
        gemm(
            Trans::No, Trans::No, n, 1, n, 1.0, &a.data, n, &xsol, n, 0.0,
            &mut b, n,
        );
        (a, b)
    }

    #[test]
    fn pow2_scaling_into_golden_zone_is_exact() {
        // Tapered precision: moving |x| toward 1 only widens the fraction
        // field, so the scaled value is exact and the round trip returns
        // the original bit pattern. (Scaling AWAY from 1 may round — that
        // is why gesv_scaled unscales only the final solution vector.)
        let mut rng = Pcg64::seed(90);
        for _ in 0..2000 {
            let sigma = rng.loguniform(1e-9, 1e9);
            let v = Posit32::from_f64(rng.normal_sigma(sigma));
            if v.is_zero() {
                continue;
            }
            let e = -v.to_f64().abs().log2().round() as i32;
            let s = scale_pow2(v, e); // |s| in [2^-0.5, 2^0.5]
            assert_eq!(s.to_f64(), v.to_f64() * (e as f64).exp2(), "{v:?} * 2^{e}");
            assert_eq!(scale_pow2(s, -e), v, "roundtrip {v:?}");
        }
    }

    #[test]
    fn scaling_restores_golden_zone_accuracy() {
        // The paper's §5.1 claim, quantified: at σ = 1e4 posit loses to
        // binary32 (Fig 7); with power-of-two equilibration it should be
        // back to ~golden-zone error.
        let n = 64;
        let (a64, b64) = problem(n, 1e4, 91);
        let a: Matrix<Posit32> = a64.cast();
        let b: Vec<Posit32> = b64.iter().map(|&v| Posit32::from_f64(v)).collect();

        // Unscaled posit solve.
        let mut lu = a.clone();
        let mut ipiv = vec![0usize; n];
        getrf(n, n, &mut lu.data, n, &mut ipiv, 16, 1).unwrap();
        let mut x0 = b.clone();
        getrs(n, 1, &lu.data, n, &ipiv, &mut x0, n);
        let e_plain = backward_error(&a64, &b64, &x0);

        // Scaled solve.
        let xs = gesv_scaled(&a, &b, 16, 1).unwrap();
        let e_scaled = backward_error(&a64, &b64, &xs);
        assert!(
            e_scaled < e_plain / 2.0,
            "scaled {e_scaled:.2e} should beat plain {e_plain:.2e}"
        );
        // And roughly match a σ=1 posit solve's error level.
        assert!(e_scaled < 3e-7, "{e_scaled:.2e}");
    }

    #[test]
    fn equilibration_exponents_center_magnitudes() {
        let n = 16;
        let (a64, _b) = problem(n, 1e6, 92);
        let a: Matrix<Posit32> = a64.cast();
        let eq = equilibrate_pow2(&a);
        // After scaling, every row max should land in [0.5, 2).
        for i in 0..n {
            let mut maxa: f64 = 0.0;
            for j in 0..n {
                maxa = maxa.max(
                    (a[(i, j)].to_f64() * ((eq.row_exp[i] + eq.col_exp[j]) as f64).exp2()).abs(),
                );
            }
            assert!((0.25..4.0).contains(&maxa), "row {i}: {maxa}");
        }
    }
}
