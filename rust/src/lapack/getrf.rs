//! LU factorization with partial pivoting (`Rgetrf` / LAPACK `dgetrf`).
//!
//! `A = P * L * U` with L unit-lower, U upper; A is overwritten by L\U and
//! `ipiv[i]` records the row swapped with row i (0-based). The blocked
//! version is right-looking (Toledo's iterative scheme, the paper's §3):
//! factor a panel of `nb` columns, apply the pivots, TRSM the row block,
//! then one big GEMM on the trailing matrix — the operation the paper
//! offloads to the FPGA/GPU.
//!
//! §Perf (decode-once factorization pipeline): [`getf2`] decodes the
//! whole panel into unpacked planes **once**, runs every elimination step
//! (pivot search, swaps, scalings, rank-1 updates) in the decoded domain,
//! and encodes each element back once at the end — instead of
//! re-decoding/encoding every operand of every rank-1 mac. The operation
//! sequence per element is exactly the scalar reference [`getf2_ref`]'s
//! (one rounding per divide/multiply/subtract, identical pivot ordering),
//! so factors and pivots are bit-identical — pinned by tests here and the
//! exhaustive Posit(8,2) sweeps in `rust/tests/factor_packed.rs`.
//! [`getf2_unpacked`] additionally hands the decoded panel back so the
//! blocked callers can marshal `L21` straight into the trailing update's
//! pack plan ([`crate::blas::PackPlan`]) while it is still hot.

use super::LapackError;
use crate::blas::{gemm::Trans, iamax, trsm_ref, trsm_unpacked, Diag, Side, Uplo};
use crate::blas::{gemm_parallel, gemm_prepacked_parallel, PackedA, PackedB, Scalar};

/// Unblocked LU with partial pivoting on an m×n panel (LAPACK `getf2`),
/// via the decode-once panel sweep. Returns the first singular column if
/// any (factorization continues). Bit-identical to [`getf2_ref`].
pub fn getf2<T: Scalar>(
    m: usize,
    n: usize,
    a: &mut [T],
    lda: usize,
    ipiv: &mut [usize],
) -> Result<(), LapackError> {
    getf2_unpacked(m, n, a, lda, ipiv).1
}

/// Decode-once `getf2`: decodes the panel into a dense column-major
/// `m*n` plane buffer once, runs the full elimination sweep there, and
/// encodes back once per element. Returns the decoded panel (post-sweep,
/// post-swaps — i.e. exactly the `L\U` planes of the written factors)
/// together with the LAPACK-style result, so blocked callers can reuse
/// the `L21` rows for the trailing update without re-decoding.
pub fn getf2_unpacked<T: Scalar>(
    m: usize,
    n: usize,
    a: &mut [T],
    lda: usize,
    ipiv: &mut [usize],
) -> (Vec<T::Unpacked>, Result<(), LapackError>) {
    debug_assert!(lda >= m.max(1), "getf2: lda {lda} < m {m}");
    debug_assert!(
        m == 0 || n == 0 || a.len() >= lda * (n - 1) + m,
        "getf2: buffer len {} too small for {m}x{n} at lda {lda}",
        a.len()
    );
    debug_assert!(ipiv.len() >= n.min(m), "getf2: ipiv len {}", ipiv.len());
    // Decode the panel once.
    let mut w: Vec<T::Unpacked> = Vec::with_capacity(m * n);
    for j in 0..n {
        for i in 0..m {
            w.push(a[i + j * lda].unpack());
        }
    }
    let mut first_singular: Option<usize> = None;
    for j in 0..n.min(m) {
        // Pivot: largest |a(i,j)| for i >= j — the decoded-domain iamax
        // (first strict maximum, exact magnitude ordering).
        let mut p = j;
        for i in j + 1..m {
            if T::unpacked_abs_gt(w[i + j * m], w[p + j * m]) {
                p = i;
            }
        }
        ipiv[j] = p;
        if T::unpacked_is_zero(w[p + j * m]) {
            first_singular.get_or_insert(j + 1);
            continue; // LAPACK records info and moves on
        }
        if p != j {
            for c in 0..n {
                w.swap(j + c * m, p + c * m);
            }
        }
        // Scale the column below the pivot: one division each.
        let piv = w[j + j * m];
        for i in j + 1..m {
            w[i + j * m] = T::uacc_store(T::uacc_div(T::uacc_load(w[i + j * m]), piv));
        }
        // Rank-1 trailing update (unblocked): a(i,l) -= a(i,j) * a(j,l) as
        // one decoded-domain mac with the exact negation folded into the
        // multiplicand (round((-x)·y) = -round(x·y)).
        for l in j + 1..n {
            let ajl = w[j + l * m];
            if T::unpacked_is_zero(ajl) {
                continue;
            }
            for i in j + 1..m {
                let acc = T::uacc_mac(T::uacc_load(w[i + l * m]), T::unpacked_neg(w[i + j * m]), ajl);
                w[i + l * m] = T::uacc_store(acc);
            }
        }
    }
    // Encode back once per element (exact marshalling: untouched elements
    // round-trip decode -> encode, touched ones are post-rounding).
    for j in 0..n {
        for i in 0..m {
            a[i + j * lda] = T::unpacked_encode(w[i + j * m]);
        }
    }
    let res = match first_singular {
        Some(i) => Err(LapackError::SingularU(i)),
        None => Ok(()),
    };
    (w, res)
}

/// The scalar reference `getf2`: per-operation decode/encode through the
/// storage type, exactly as before the decode-once pipeline. Retained as
/// the bit-identity ground truth and the factorization bench baseline.
pub fn getf2_ref<T: Scalar>(
    m: usize,
    n: usize,
    a: &mut [T],
    lda: usize,
    ipiv: &mut [usize],
) -> Result<(), LapackError> {
    let mut first_singular: Option<usize> = None;
    for j in 0..n.min(m) {
        // Pivot: largest |a(i,j)| for i >= j.
        let p = j + iamax(m - j, &a[j + j * lda..j + j * lda + (m - j)], 1);
        ipiv[j] = p;
        if a[p + j * lda].is_zero() {
            first_singular.get_or_insert(j + 1);
            continue; // LAPACK records info and moves on
        }
        if p != j {
            crate::blas::swap_rows(a, lda, n, j, p);
        }
        // Scale the column below the pivot: one division each.
        let piv = a[j + j * lda];
        for i in j + 1..m {
            a[i + j * lda] = a[i + j * lda].div(piv);
        }
        // Rank-1 trailing update (unblocked): a(i,l) -= a(i,j) * a(j,l).
        for l in j + 1..n {
            let ajl = a[j + l * lda];
            if ajl.is_zero() {
                continue;
            }
            for i in j + 1..m {
                let prod = a[i + j * lda].mul(ajl);
                a[i + l * lda] = a[i + l * lda].sub(prod);
            }
        }
    }
    match first_singular {
        Some(i) => Err(LapackError::SingularU(i)),
        None => Ok(()),
    }
}

/// Apply row interchanges `ipiv[k1..k2]` to the columns of `a` (`laswp`).
pub fn laswp<T: Scalar>(
    n: usize,
    a: &mut [T],
    lda: usize,
    k1: usize,
    k2: usize,
    ipiv: &[usize],
) {
    for i in k1..k2 {
        let p = ipiv[i];
        if p != i {
            crate::blas::swap_rows(a, lda, n, i, p);
        }
    }
}

/// Blocked right-looking LU with partial pivoting (LAPACK `getrf`),
/// running the decode-once pipeline end to end: unpacked panel, unpacked
/// TRSM, and a trailing GEMM whose operands are marshalled from the
/// still-decoded panel/TRSM planes into a prepacked slab pair — the
/// scalar matrix is never re-decoded (nor re-packed) for the update.
///
/// `nb` is the panel width; `threads` parallelizes the trailing GEMM.
/// Bit-identical for any `nb`/`threads` — the k-dimension of every GEMM is
/// a full panel, never split (DESIGN.md §7) — and bit-identical to the
/// scalar-path [`getrf_ref`] (decode is pure; every kernel keeps its
/// per-operation rounding points).
pub fn getrf<T: Scalar>(
    m: usize,
    n: usize,
    a: &mut [T],
    lda: usize,
    ipiv: &mut [usize],
    nb: usize,
    threads: usize,
) -> Result<(), LapackError> {
    let k = m.min(n);
    if nb <= 1 || nb >= k {
        return getf2(m, n, a, lda, ipiv);
    }
    let mut info: Option<LapackError> = None;
    let mut j = 0;
    while j < k {
        let jb = nb.min(k - j);
        let pm = m - j; // panel height
        // --- Panel factorization (host CPU in the paper's split); the
        // decoded panel is kept for the trailing update's A-side slabs.
        let panel_u;
        {
            let panel = &mut a[j + j * lda..];
            let mut piv = vec![0usize; jb];
            let (pu, res) = getf2_unpacked(pm, jb, panel, lda, &mut piv);
            panel_u = pu;
            if let Err(e) = res {
                info.get_or_insert(match e {
                    LapackError::SingularU(i) => LapackError::SingularU(i + j),
                    other => other,
                });
            }
            for (t, &p) in ipiv[j..j + jb].iter_mut().zip(&piv) {
                *t = p + j;
            }
        }
        // --- Apply the panel's pivots to the rest of the matrix. --------
        // Left of the panel:
        laswp(j, a, lda, j, j + jb, ipiv);
        if j + jb < n {
            // Right of the panel:
            laswp(n - j - jb, &mut a[(j + jb) * lda..], lda, j, j + jb, ipiv);
            // --- Row block: U12 = L11^{-1} A12 (decode-once TRSM; its
            // decoded output becomes the update's B-side slabs). ---------
            let ncols = n - j - jb;
            let (a11_part, a12_part) = a.split_at_mut((j + jb) * lda);
            let a11 = &a11_part[j + j * lda..];
            let a12 = &mut a12_part[j..];
            let u12_u = trsm_unpacked(
                Side::Left,
                Uplo::Lower,
                Trans::No,
                Diag::Unit,
                jb,
                ncols,
                T::one(),
                a11,
                lda,
                a12,
                lda,
            );
            if j + jb < m {
                // --- Trailing update: A22 -= L21 * U12 (the offloaded
                // GEMM), with both operands marshalled from the hot
                // decoded planes — no decode, no scalar staging copy.
                let nrows = m - j - jb;
                let pa = PackedA::<T>::from_fn(nrows, jb, |i, l| panel_u[(jb + i) + l * pm]);
                let pb = PackedB::<T>::from_fn(jb, ncols, |l, c| u12_u[l + c * jb]);
                let (_, right) = a.split_at_mut((j + jb) * lda);
                let a22 = &mut right[j + jb..];
                let minus_one = T::zero().sub(T::one());
                gemm_prepacked_parallel(
                    threads, nrows, ncols, jb, minus_one, &pa, &pb, T::one(), a22, lda,
                );
            }
        }
        j += jb;
    }
    match info {
        Some(e) => Err(e),
        None => Ok(()),
    }
}

/// The pre-pipeline blocked LU: scalar panel ([`getf2_ref`]), scalar TRSM
/// ([`trsm_ref`]) and a trailing GEMM that re-packs its operands from the
/// scalar matrix every blocked step. Retained verbatim as the
/// bit-identity ground truth and the `BENCH_factor.json` baseline.
pub fn getrf_ref<T: Scalar>(
    m: usize,
    n: usize,
    a: &mut [T],
    lda: usize,
    ipiv: &mut [usize],
    nb: usize,
    threads: usize,
) -> Result<(), LapackError> {
    let k = m.min(n);
    if nb <= 1 || nb >= k {
        return getf2_ref(m, n, a, lda, ipiv);
    }
    let mut info: Option<LapackError> = None;
    let mut j = 0;
    while j < k {
        let jb = nb.min(k - j);
        // --- Panel factorization (host CPU in the paper's split). -------
        {
            let panel = &mut a[j + j * lda..];
            let mut piv = vec![0usize; jb];
            if let Err(e) = getf2_ref(m - j, jb, panel, lda, &mut piv) {
                info.get_or_insert(match e {
                    LapackError::SingularU(i) => LapackError::SingularU(i + j),
                    other => other,
                });
            }
            for (t, &p) in ipiv[j..j + jb].iter_mut().zip(&piv) {
                *t = p + j;
            }
        }
        // --- Apply the panel's pivots to the rest of the matrix. --------
        // Left of the panel:
        laswp(j, a, lda, j, j + jb, ipiv);
        if j + jb < n {
            // Right of the panel:
            laswp(n - j - jb, &mut a[(j + jb) * lda..], lda, j, j + jb, ipiv);
            // --- Row block: U12 = L11^{-1} A12. --------------------------
            let (a11_part, a12_part) = a.split_at_mut((j + jb) * lda);
            let a11 = &a11_part[j + j * lda..];
            let a12 = &mut a12_part[j..];
            trsm_ref(
                Side::Left,
                Uplo::Lower,
                Trans::No,
                Diag::Unit,
                jb,
                n - j - jb,
                T::one(),
                a11,
                lda,
                a12,
                lda,
            );
            if j + jb < m {
                // --- Trailing update: A22 -= L21 * U12 (the offloaded GEMM).
                // U12 (rows j..j+jb of the columns right of the panel) is
                // copied into a packed jb x ncols buffer — the same
                // panel-sized staging the paper does when streaming the
                // update operands to the FPGA/GPU — which also resolves the
                // A22/U12 borrow overlap (same columns, disjoint rows).
                let ncols = n - j - jb;
                let mut u12 = vec![T::zero(); jb * ncols];
                for c in 0..ncols {
                    let base = j + (j + jb + c) * lda;
                    u12[c * jb..(c + 1) * jb].copy_from_slice(&a[base..base + jb]);
                }
                let (left, right) = a.split_at_mut((j + jb) * lda);
                let l21 = &left[(j + jb) + j * lda..];
                let a22 = &mut right[j + jb..];
                let minus_one = T::zero().sub(T::one());
                gemm_parallel(
                    threads,
                    Trans::No,
                    Trans::No,
                    m - j - jb,
                    ncols,
                    jb,
                    minus_one,
                    l21,
                    lda,
                    &u12,
                    jb,
                    T::one(),
                    a22,
                    lda,
                );
            }
        }
        j += jb;
    }
    match info {
        Some(e) => Err(e),
        None => Ok(()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::{Matrix, Scalar};
    use crate::posit::Posit32;
    use crate::rng::Pcg64;

    fn reconstruct<T: Scalar>(lu: &Matrix<T>, ipiv: &[usize], n: usize) -> Matrix<f64> {
        // P^T * L * U in f64 (apply swaps in reverse to undo).
        let mut l = Matrix::<f64>::identity(n);
        let mut u = Matrix::<f64>::zeros(n, n);
        for j in 0..n {
            for i in 0..n {
                let v = lu[(i, j)].to_f64();
                if i > j {
                    l[(i, j)] = v;
                } else {
                    u[(i, j)] = v;
                }
            }
        }
        let mut plu = Matrix::<f64>::zeros(n, n);
        crate::blas::gemm(
            crate::blas::Trans::No, crate::blas::Trans::No, n, n, n, 1.0,
            &l.data, n, &u.data, n, 0.0, &mut plu.data, n,
        );
        // Undo pivoting: apply swaps in reverse order to rows.
        for i in (0..n).rev() {
            if ipiv[i] != i {
                crate::blas::swap_rows(&mut plu.data, n, n, i, ipiv[i]);
            }
        }
        plu
    }

    #[test]
    fn factorization_reconstructs_f64() {
        let n = 48;
        let mut rng = Pcg64::seed(100);
        let a0 = Matrix::<f64>::random_normal(n, n, 1.0, &mut rng);
        let mut a = a0.clone();
        let mut ipiv = vec![0usize; n];
        getrf(n, n, &mut a.data, n, &mut ipiv, 16, 1).unwrap();
        let plu = reconstruct(&a, &ipiv, n);
        assert!(plu.max_abs_diff(&a0) < 1e-12 * (n as f64));
    }

    #[test]
    fn blocked_matches_unblocked_bitwise_posit() {
        let n = 37; // deliberately not a multiple of nb
        let mut rng = Pcg64::seed(101);
        let a0 = Matrix::<Posit32>::random_normal(n, n, 1.0, &mut rng);
        let mut a1 = a0.clone();
        let mut a2 = a0.clone();
        let mut p1 = vec![0usize; n];
        let mut p2 = vec![0usize; n];
        getf2(n, n, &mut a1.data, n, &mut p1).unwrap();
        getrf(n, n, &mut a2.data, n, &mut p2, 8, 2).unwrap();
        // Pivoting decisions must be identical...
        assert_eq!(p1, p2);
        // ...but the arithmetic differs: getf2 applies rank-1 updates per
        // column (nb-1 roundings interleaved), getrf defers to a blocked
        // GEMM. LAPACK has the same property. What must hold: both are
        // valid factorizations with comparable residual.
        let r1 = reconstruct(&a1, &p1, n);
        let r2 = reconstruct(&a2, &p2, n);
        let a0f: Matrix<f64> = a0.cast();
        let (e1, e2) = (r1.max_abs_diff(&a0f), r2.max_abs_diff(&a0f));
        assert!(e1 < 1e-4 && e2 < 1e-4, "residuals {e1} {e2}");
    }

    #[test]
    fn decode_once_pipeline_matches_scalar_reference_bitwise() {
        // getf2 vs getf2_ref and getrf vs getrf_ref on posit data across
        // the dynamic range: factors, pivots and info must be identical.
        let mut rng = Pcg64::seed(103);
        let val = |rng: &mut Pcg64| {
            let e = (rng.next_u32() % 60) as i32 - 30;
            Posit32::from_f64(rng.normal() * 2f64.powi(e))
        };
        for (m, n) in [(19usize, 19usize), (23, 11), (9, 21)] {
            let a0 = Matrix::<Posit32>::from_fn(m, n, |_, _| val(&mut rng));
            let kk = m.min(n);
            let mut a1 = a0.clone();
            let mut a2 = a0.clone();
            let (mut p1, mut p2) = (vec![0usize; kk], vec![0usize; kk]);
            let r1 = getf2_ref(m, n, &mut a1.data, m, &mut p1);
            let r2 = getf2(m, n, &mut a2.data, m, &mut p2);
            assert_eq!(r1, r2, "{m}x{n} info");
            assert_eq!(p1, p2, "{m}x{n} pivots");
            assert_eq!(a1.data, a2.data, "{m}x{n} factors");

            let mut b1 = a0.clone();
            let mut b2 = a0.clone();
            let (mut q1, mut q2) = (vec![0usize; kk], vec![0usize; kk]);
            let s1 = getrf_ref(m, n, &mut b1.data, m, &mut q1, 5, 2);
            let s2 = getrf(m, n, &mut b2.data, m, &mut q2, 5, 2);
            assert_eq!(s1, s2, "{m}x{n} blocked info");
            assert_eq!(q1, q2, "{m}x{n} blocked pivots");
            assert_eq!(b1.data, b2.data, "{m}x{n} blocked factors");
        }
    }

    #[test]
    fn pivoting_handles_zero_leading_element() {
        // First pivot is 0 -> must swap, not fail.
        let mut a = Matrix::<f64>::from_fn(3, 3, |i, j| match (i, j) {
            (0, 0) => 0.0,
            _ => (i * 3 + j) as f64 + 1.0,
        });
        let a0 = a.clone();
        let mut ipiv = vec![0usize; 3];
        getrf(3, 3, &mut a.data, 3, &mut ipiv, 2, 1).unwrap();
        let plu = reconstruct(&a, &ipiv, 3);
        assert!(plu.max_abs_diff(&a0) < 1e-12);
        assert_ne!(ipiv[0], 0);
    }

    #[test]
    fn singular_matrix_reports_info() {
        // Rank-1 matrix: must report SingularU, like LAPACK info > 0.
        let n = 4;
        let mut a = Matrix::<f64>::from_fn(n, n, |i, j| ((i + 1) * (j + 1)) as f64);
        let mut ipiv = vec![0usize; n];
        let err = getrf(n, n, &mut a.data, n, &mut ipiv, 2, 1).unwrap_err();
        assert!(matches!(err, LapackError::SingularU(_)));
    }

    #[test]
    fn rectangular_shapes() {
        for (m, n) in [(10, 6), (6, 10)] {
            let mut rng = Pcg64::seed((m * 100 + n) as u64);
            let a0 = Matrix::<f64>::random_normal(m, n, 1.0, &mut rng);
            let mut a = a0.clone();
            let mut ipiv = vec![0usize; m.min(n)];
            getrf(m, n, &mut a.data, m, &mut ipiv, 4, 1).unwrap();
            // L (m x k) * U (k x n) with pivots undone == A0.
            let k = m.min(n);
            let mut l = Matrix::<f64>::zeros(m, k);
            let mut u = Matrix::<f64>::zeros(k, n);
            for j in 0..n {
                for i in 0..m {
                    let v = a[(i, j)];
                    if j < k && i > j {
                        l[(i, j)] = v;
                    }
                    if i < k && i <= j {
                        u[(i, j)] = v;
                    }
                }
            }
            for i in 0..k {
                l[(i, i)] = 1.0;
            }
            let mut plu = Matrix::<f64>::zeros(m, n);
            crate::blas::gemm(
                crate::blas::Trans::No, crate::blas::Trans::No, m, n, k, 1.0,
                &l.data, m, &u.data, k, 0.0, &mut plu.data, m,
            );
            for i in (0..k).rev() {
                if ipiv[i] != i {
                    crate::blas::swap_rows(&mut plu.data, m, n, i, ipiv[i]);
                }
            }
            assert!(plu.max_abs_diff(&a0) < 1e-12, "{m}x{n}");
        }
    }
}
