//! Mixed-precision iterative refinement with quire residuals — the
//! posit-native answer to accuracy loss outside the golden zone.
//!
//! `gesv_refine` factorizes once in Posit(32,2), then iterates
//! `r = b - A x̂` (each component an **exact** quire dot product, one
//! rounding), solves `A d = r` with the existing factors, and updates
//! `x̂ += d`. This is the classic LAPACK `gerfs` scheme with the quire
//! playing the role of extended-precision residual accumulation — the
//! capability the posit standard builds in and the paper's ref. [2]
//! recommends for linear algebra. Used by the `fig7b`-adjacent extension
//! experiments and exercised against ill-conditioned systems in tests.

use super::{getrf, getrs, LapackError};
use crate::blas::Matrix;
use crate::posit::{quire::Quire, Posit32};

/// Result of a refined solve.
#[derive(Clone, Debug)]
pub struct RefineResult {
    pub x: Vec<Posit32>,
    /// Iterations actually performed.
    pub iters: usize,
    /// Max |d_i / x_i| at the last step (convergence measure).
    pub last_correction: f64,
}

/// Solve `A x = b` in Posit(32,2) with quire-refined residuals.
///
/// `a` is consumed into its LU factors. Stops after `max_iter` rounds or
/// when the correction stalls below ~1 ulp.
pub fn gesv_refine(
    mut a: Matrix<Posit32>,
    b: &[Posit32],
    nb: usize,
    threads: usize,
    max_iter: usize,
) -> Result<RefineResult, LapackError> {
    let n = a.rows;
    assert_eq!(a.cols, n);
    assert_eq!(b.len(), n);
    let a0 = a.clone(); // residuals need the original matrix
    let mut ipiv = vec![0usize; n];
    getrf(n, n, &mut a.data, n, &mut ipiv, nb, threads)?;

    let mut x = b.to_vec();
    getrs(n, 1, &a.data, n, &ipiv, &mut x, n);

    let mut last_correction = f64::INFINITY;
    let mut iters = 0;
    for _ in 0..max_iter {
        // r_i = b_i - Σ_l a_il x_l, exactly accumulated, rounded once.
        let mut r = vec![Posit32::ZERO; n];
        for i in 0..n {
            let mut q = Quire::new();
            q.add_posit(b[i].0);
            for l in 0..n {
                q.sub_product(a0[(i, l)].0, x[l].0);
            }
            r[i] = Posit32(q.to_posit_bits());
        }
        // d = A^{-1} r via the existing factors.
        getrs(n, 1, &a.data, n, &ipiv, &mut r, n);
        // x += d; track the relative size of the correction.
        let mut corr: f64 = 0.0;
        for i in 0..n {
            let xi = x[i].to_f64();
            let di = r[i].to_f64();
            if xi != 0.0 {
                corr = corr.max((di / xi).abs());
            }
            x[i] = x[i] + r[i];
        }
        iters += 1;
        if corr >= last_correction || corr < 5e-9 {
            last_correction = corr.min(last_correction);
            break;
        }
        last_correction = corr;
    }
    Ok(RefineResult {
        x,
        iters,
        last_correction,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::{gemm, Trans};
    use crate::lapack::backward_error;
    use crate::rng::Pcg64;

    fn setup(n: usize, sigma: f64, seed: u64) -> (Matrix<f64>, Vec<f64>, Vec<f64>) {
        let mut rng = Pcg64::seed(seed);
        let a = Matrix::<f64>::random_normal(n, n, sigma, &mut rng);
        let xsol = vec![1.0 / (n as f64).sqrt(); n];
        let mut b = vec![0.0; n];
        gemm(
            Trans::No, Trans::No, n, 1, n, 1.0, &a.data, n, &xsol, n, 0.0,
            &mut b, n,
        );
        (a, xsol, b)
    }

    #[test]
    fn refinement_beats_plain_solve() {
        let n = 64;
        let (a64, _xsol, b64) = setup(n, 1.0, 80);
        let a: Matrix<Posit32> = a64.cast();
        let b: Vec<Posit32> = b64.iter().map(|&v| Posit32::from_f64(v)).collect();

        // Plain solve.
        let mut lu = a.clone();
        let mut ipiv = vec![0usize; n];
        getrf(n, n, &mut lu.data, n, &mut ipiv, 16, 1).unwrap();
        let mut x0 = b.clone();
        getrs(n, 1, &lu.data, n, &ipiv, &mut x0, n);
        let e_plain = backward_error(&a64, &b64, &x0);

        // Refined.
        let r = gesv_refine(a, &b, 16, 1, 5).unwrap();
        let e_ref = backward_error(&a64, &b64, &r.x);
        assert!(r.iters >= 1);
        assert!(
            e_ref < e_plain / 3.0,
            "refinement {e_ref:.2e} should beat plain {e_plain:.2e}"
        );
        // Refined solutions approach the casting limit of the RHS.
        assert!(e_ref < 5e-8, "{e_ref:.2e}");
    }

    #[test]
    fn refinement_helps_outside_golden_zone() {
        // σ = 1e2 is where posit starts losing to binary32 (Fig 7); the
        // quire recovers a digit or two.
        let n = 48;
        let (a64, _x, b64) = setup(n, 1e2, 81);
        let a: Matrix<Posit32> = a64.cast();
        let b: Vec<Posit32> = b64.iter().map(|&v| Posit32::from_f64(v)).collect();
        let mut lu = a.clone();
        let mut ipiv = vec![0usize; n];
        getrf(n, n, &mut lu.data, n, &mut ipiv, 16, 1).unwrap();
        let mut x0 = b.clone();
        getrs(n, 1, &lu.data, n, &ipiv, &mut x0, n);
        let e_plain = backward_error(&a64, &b64, &x0);
        let r = gesv_refine(a, &b, 16, 1, 5).unwrap();
        let e_ref = backward_error(&a64, &b64, &r.x);
        assert!(e_ref < e_plain, "{e_ref:.2e} vs {e_plain:.2e}");
    }

    #[test]
    fn singular_matrix_propagates_error() {
        let n = 8;
        let a = Matrix::<Posit32>::from_fn(n, n, |i, j| {
            Posit32::from_f64(((i + 1) * (j + 1)) as f64)
        });
        let b = vec![Posit32::ONE; n];
        assert!(gesv_refine(a, &b, 4, 1, 3).is_err());
    }
}
