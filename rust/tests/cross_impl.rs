//! Cross-implementation property tests: the two Rust posit engines
//! (branchless and SoftPosit-style) plus algebraic invariants, over large
//! randomized sweeps with replayable seeds (`prop::check`).

use posit_accel::posit::generic::{NoTrace, PositSpec};
use posit_accel::posit::{self, quire::Quire, Posit32};
use posit_accel::prop::check;
use posit_accel::rng::Pcg64;

fn any_bits(rng: &mut Pcg64) -> u32 {
    match rng.below(6) {
        0 => rng.next_u32(),
        1 => Posit32::from_f64(rng.normal()).0,
        2 => Posit32::from_f64(rng.normal_sigma(1e8)).0,
        3 => Posit32::from_f64(rng.normal_sigma(1e-12)).0,
        4 => [0u32, 0x8000_0000, 0x7FFF_FFFF, 1, 0x4000_0000][rng.below(5) as usize],
        _ => rng.next_u32() & 0x8000_00FF, // tiny magnitudes + sign
    }
}

#[test]
fn engines_agree_on_all_ops() {
    let spec = PositSpec::P32;
    let mut t = NoTrace;
    check(
        "branchless == softposit-style",
        30_000,
        |rng| (any_bits(rng), any_bits(rng)),
        |&(a, b)| {
            for (name, fast, slow) in [
                ("add", posit::add(a, b), spec.add(a, b, &mut NoTrace)),
                ("mul", posit::mul(a, b), spec.mul(a, b, &mut NoTrace)),
                ("div", posit::div(a, b), spec.div(a, b, &mut NoTrace)),
                ("sqrt", posit::sqrt(a), spec.sqrt(a, &mut NoTrace)),
            ] {
                if fast != slow {
                    return Err(format!("{name}: fast {fast:#010x} != slow {slow:#010x}"));
                }
            }
            Ok(())
        },
    );
    let _ = &mut t;
}

#[test]
fn addition_is_commutative_and_has_identity() {
    check(
        "add commutative + identity",
        20_000,
        |rng| (any_bits(rng), any_bits(rng)),
        |&(a, b)| {
            if posit::add(a, b) != posit::add(b, a) {
                return Err("not commutative".into());
            }
            if a != posit::NAR_BITS && posit::add(a, posit::ZERO_BITS) != a {
                return Err("0 is not identity".into());
            }
            Ok(())
        },
    );
}

#[test]
fn multiplication_identities() {
    check(
        "mul identities",
        20_000,
        |rng| (any_bits(rng), any_bits(rng)),
        |&(a, b)| {
            if posit::mul(a, b) != posit::mul(b, a) {
                return Err("not commutative".into());
            }
            if a != posit::NAR_BITS {
                if posit::mul(a, posit::ONE_BITS) != a {
                    return Err("1 is not identity".into());
                }
                // x * -1 == -x exactly.
                if posit::mul(a, posit::neg(posit::ONE_BITS)) != posit::neg(a) {
                    return Err("-1 scaling not exact".into());
                }
            }
            Ok(())
        },
    );
}

#[test]
fn rounding_is_correct_vs_f64_when_exact() {
    // When the f64 result is exactly representable near the golden zone,
    // posit must return it exactly.
    check(
        "exact small-integer arithmetic",
        10_000,
        |rng| (rng.below(4096) as i64 - 2048, rng.below(4096) as i64 - 2048),
        |&(x, y)| {
            let (a, b) = (
                Posit32::from_f64(x as f64),
                Posit32::from_f64(y as f64),
            );
            if (a + b).to_f64() != (x + y) as f64 {
                return Err(format!("{x}+{y} -> {}", (a + b).to_f64()));
            }
            let prod = x * y;
            if prod.abs() <= 1 << 26 && (a * b).to_f64() != prod as f64 {
                return Err(format!("{x}*{y} -> {}", (a * b).to_f64()));
            }
            Ok(())
        },
    );
}

#[test]
fn division_roundtrip_bounds() {
    // (a / b) * b must be within 2 ulp-ish of a (two roundings), checked
    // via f64 relative error in the golden zone.
    check(
        "div-mul roundtrip",
        10_000,
        |rng| {
            (
                Posit32::from_f64(rng.normal()).0,
                Posit32::from_f64(rng.normal()).0,
            )
        },
        |&(a, b)| {
            if a == posit::ZERO_BITS || b == posit::ZERO_BITS {
                return Ok(());
            }
            let q = posit::div(a, b);
            let back = posit::mul(q, b);
            let (va, vb) = (Posit32(a).to_f64(), Posit32(back).to_f64());
            let rel = ((va - vb) / va).abs();
            if rel > 1e-6 {
                return Err(format!("roundtrip rel err {rel}"));
            }
            Ok(())
        },
    );
}

#[test]
fn sqrt_squares_back() {
    check(
        "sqrt(x)^2 ~ x",
        10_000,
        |rng| Posit32::from_f64(rng.normal_sigma(10.0).abs()).0,
        |&a| {
            if a == posit::ZERO_BITS {
                return Ok(());
            }
            let r = posit::sqrt(a);
            let sq = posit::mul(r, r);
            let (va, vs) = (Posit32(a).to_f64(), Posit32(sq).to_f64());
            let rel = ((va - vs) / va).abs();
            if rel > 1e-6 {
                return Err(format!("sqrt err {rel}"));
            }
            Ok(())
        },
    );
}

#[test]
fn quire_dot_matches_f64_for_moderate_sums() {
    // With values in the golden zone and moderate lengths the f64 dot is
    // exact enough (53 bits) that quire == round(f64 result).
    check(
        "quire dot == f64 dot rounded",
        300,
        |rng| {
            let n = 1 + rng.below(64) as usize;
            let xs: Vec<u32> = (0..n)
                .map(|_| Posit32::from_f64((rng.below(1024) as f64 - 512.0) / 256.0).0)
                .collect();
            let ys: Vec<u32> = (0..n)
                .map(|_| Posit32::from_f64((rng.below(1024) as f64 - 512.0) / 256.0).0)
                .collect();
            (xs, ys)
        },
        |(xs, ys)| {
            let mut q = Quire::new();
            for (&x, &y) in xs.iter().zip(ys) {
                q.add_product(x, y);
            }
            let exact: f64 = xs
                .iter()
                .zip(ys)
                .map(|(&x, &y)| Posit32(x).to_f64() * Posit32(y).to_f64())
                .sum();
            let want = Posit32::from_f64(exact).0;
            let got = q.to_posit_bits();
            if got != want {
                return Err(format!("quire {got:#x} != {want:#x} (exact {exact})"));
            }
            Ok(())
        },
    );
}

#[test]
fn ordering_is_total_and_matches_values() {
    check(
        "bit ordering == value ordering",
        20_000,
        |rng| (any_bits(rng), any_bits(rng)),
        |&(a, b)| {
            if a == posit::NAR_BITS || b == posit::NAR_BITS {
                return Ok(());
            }
            let (pa, pb) = (Posit32(a), Posit32(b));
            let by_val = pa.to_f64().partial_cmp(&pb.to_f64()).unwrap();
            if pa.cmp(&pb) != by_val {
                return Err(format!("{pa:?} vs {pb:?}"));
            }
            Ok(())
        },
    );
}

#[test]
fn generic_engine_small_formats_roundtrip() {
    // Posit(16,1) and Posit(8,2): exhaustive f64 roundtrips + negation
    // involution (the ablation formats of the paper's future work, §7).
    for spec in [PositSpec::P16, PositSpec::P8, PositSpec::P8E0, PositSpec::P16E2] {
        for bits in 0..(1u32 << spec.nbits) {
            if bits == spec.nar() {
                continue;
            }
            let v = spec.to_f64(bits);
            assert_eq!(spec.from_f64(v), bits, "{spec:?} {bits:#x}");
            assert_eq!(spec.negate(spec.negate(bits)), bits);
        }
    }
}

#[test]
fn round_unpacked_equals_pack_unpack() {
    // The fused-GEMM fast path must be indistinguishable from the full
    // encoder across the whole scale range (including the fallback zone).
    check(
        "round_unpacked == unpack(pack(...))",
        50_000,
        |rng| {
            let scale = (rng.below(2 * 130 + 1) as i32) - 130; // beyond ±120 too
            let sig = rng.next_u64() | (1u64 << 63);
            (rng.below(2) == 1, scale, sig)
        },
        |&(neg, scale, sig)| {
            let fast = posit::round_unpacked(neg, scale, sig);
            let bits = posit_accel::posit::pack32(neg, scale, sig);
            let slow = posit_accel::posit::unpack32(bits);
            if fast != slow {
                return Err(format!("{fast:?} != {slow:?}"));
            }
            Ok(())
        },
    );
}
