//! Exhaustive oracle validation of the quire accumulator on Posit(8,2).
//!
//! Posit(8,2) is small enough to check *every* case: each non-special
//! pattern is `±m · 2^(scale-3)` with `m ∈ [8,15]` and `scale ∈ [-24,24]`,
//! i.e. an integer multiple of `2^-27`. Products of two such values are
//! integer multiples of `2^-54`, and short sums of products fit an `i128`
//! with room to spare — so an `i128` fixed-point accumulator at scale
//! `2^-54` is an *exact* oracle for the quire. (An `f64` oracle would not
//! be: 3-term sums reach ~2^57 > 2^53, past binary64's exact-integer
//! range.) The oracle rounds through the same public
//! [`PositSpec::encode`] the quire's own `to_bits` uses — normalize the
//! `i128` to (sign, scale, Q1.63 significand with sticky), exactly the
//! quire-rounding contract — so any mismatch pins a bug in the 512-bit
//! *accumulation*, the thing this suite exists to prove.
//!
//! Pinned here, per the accum=quire tentpole:
//! * all 256 × 256 `add_product` / `sub_product` pairs, bit-for-bit;
//! * chained 3-term dots over a strided sweep plus the extreme patterns
//!   (maxpos, minpos, ±1, NaR), bit-for-bit, including cancellation to
//!   exact zero;
//! * NaR absorption, zero products, saturation to ±maxpos, and the
//!   never-round-to-zero rule;
//! * `GQuire::<32,2>` vs the dedicated Posit(32,2) [`Quire`] on random
//!   bit patterns (the two independent implementations must agree).

use posit_accel::posit::generic::{NoTrace, PositSpec};
use posit_accel::posit::quire::{GQuire, Quire};
use posit_accel::rng::Pcg64;

const SPEC: PositSpec = PositSpec { nbits: 8, es: 2 };
type Q8 = GQuire<8, 2>;

/// Exact fixed-point value of a P(8,2) pattern, in units of 2^-27.
/// Zero -> Some(0); NaR -> None.
fn fixed27(bits: u32) -> Option<i64> {
    if bits & SPEC.mask() == SPEC.nar() {
        return None;
    }
    match SPEC.decode(bits, &mut NoTrace) {
        None => Some(0), // decode returns None for both 0 and NaR; NaR handled above
        Some(d) => {
            // P(8,2) significands carry at most 3 fraction bits: Q1.63
            // sig = m << 60 with m in [8, 15].
            assert_eq!(d.sig & ((1u64 << 60) - 1), 0, "bits {bits:#04x}");
            let m = (d.sig >> 60) as i64;
            assert!((8..=15).contains(&m));
            assert!((-24..=24).contains(&d.scale), "bits {bits:#04x}");
            // value = m * 2^(scale-3) = (m << (scale+24)) * 2^-27.
            let v = m << (d.scale + 24);
            Some(if d.neg { -v } else { v })
        }
    }
}

/// Round an exact sum (in units of 2^-54) to the nearest P(8,2) pattern,
/// with posit semantics: RNE in the encoding, saturation at ±maxpos,
/// nonzero never rounds to zero. Mirrors the quire-rounding contract:
/// normalize to (neg, scale, Q1.63 sig + sticky) and defer to `encode`.
fn oracle_round(sum: i128) -> u32 {
    if sum == 0 {
        return 0;
    }
    let neg = sum < 0;
    let mag = sum.unsigned_abs();
    let msb = 127 - mag.leading_zeros() as i32;
    let scale = msb - 54;
    let sig = if msb >= 63 {
        let sh = (msb - 63) as u32;
        let kept = (mag >> sh) as u64;
        let sticky = mag & ((1u128 << sh) - 1) != 0;
        kept | sticky as u64
    } else {
        (mag as u64) << (63 - msb)
    };
    SPEC.encode(neg, scale, sig, &mut NoTrace)
}

#[test]
fn exhaustive_pairs_match_exact_oracle() {
    let nar = SPEC.nar();
    for a in 0..256u32 {
        for b in 0..256u32 {
            if a == nar || b == nar {
                // NaR poisons the accumulation, for add and sub alike.
                for subtract in [false, true] {
                    let mut q = Q8::new();
                    if subtract {
                        q.sub_product(a, b);
                    } else {
                        q.add_product(a, b);
                    }
                    assert!(q.is_nar(), "NaR operand a={a:#04x} b={b:#04x}");
                    assert_eq!(q.to_bits(), nar);
                }
                continue;
            }
            let prod = fixed27(a).unwrap() as i128 * fixed27(b).unwrap() as i128;

            let mut q = Q8::new();
            q.add_product(a, b);
            assert_eq!(
                q.to_bits(),
                oracle_round(prod),
                "add_product a={a:#04x} b={b:#04x}"
            );
            assert_eq!(q.is_zero(), prod == 0, "zero state a={a:#04x} b={b:#04x}");

            let mut q = Q8::new();
            q.sub_product(a, b);
            assert_eq!(
                q.to_bits(),
                oracle_round(-prod),
                "sub_product a={a:#04x} b={b:#04x}"
            );
        }
    }
}

/// The strided sweep plus every special pattern — NaR, zero, ±maxpos,
/// ±minpos, ±1 — so chains cover saturation and exact cancellation.
fn sweep(step: usize) -> Vec<u32> {
    let mut v: Vec<u32> = (0..256).step_by(step).map(|x| x as u32).collect();
    v.extend([0x00, 0x01, 0x7F, 0x80, 0x81, 0xFF, 0x40, 0xC0]);
    v
}

#[test]
fn chained_three_term_dots_match_exact_oracle() {
    let nar = SPEC.nar();
    for &a in &sweep(5) {
        for &b in &sweep(7) {
            for &c in &sweep(11) {
                // Two chains per triple: all-add (a.b + b.c + c.a) and a
                // mixed add/sub chain (a.b - b.c + c.a).
                let mut qadd = Q8::new();
                qadd.add_product(a, b);
                qadd.add_product(b, c);
                qadd.add_product(c, a);
                let mut qmix = Q8::new();
                qmix.add_product(a, b);
                qmix.sub_product(b, c);
                qmix.add_product(c, a);

                if a == nar || b == nar || c == nar {
                    assert_eq!(qadd.to_bits(), nar, "a={a:#04x} b={b:#04x} c={c:#04x}");
                    assert_eq!(qmix.to_bits(), nar, "a={a:#04x} b={b:#04x} c={c:#04x}");
                    continue;
                }
                let (va, vb, vc) = (
                    fixed27(a).unwrap() as i128,
                    fixed27(b).unwrap() as i128,
                    fixed27(c).unwrap() as i128,
                );
                assert_eq!(
                    qadd.to_bits(),
                    oracle_round(va * vb + vb * vc + vc * va),
                    "add chain a={a:#04x} b={b:#04x} c={c:#04x}"
                );
                assert_eq!(
                    qmix.to_bits(),
                    oracle_round(va * vb - vb * vc + vc * va),
                    "mixed chain a={a:#04x} b={b:#04x} c={c:#04x}"
                );
                // The fused-dot helper is the same chain.
                assert_eq!(
                    Q8::dot(&[a, b, c], &[b, c, a]),
                    qadd.to_bits(),
                    "dot a={a:#04x} b={b:#04x} c={c:#04x}"
                );
            }
        }
    }
}

#[test]
fn quire_edge_semantics() {
    let nar = SPEC.nar();
    let maxpos = SPEC.maxpos(); // 0x7F = 2^24
    let minpos = SPEC.minpos(); // 0x01 = 2^-24
    let one = 0x40u32;

    // Saturation: maxpos^2 is far past maxpos; stacking more keeps it there.
    let mut q = Q8::new();
    q.add_product(maxpos, maxpos);
    assert_eq!(q.to_bits(), maxpos);
    q.add_product(maxpos, maxpos);
    assert_eq!(q.to_bits(), maxpos);
    assert_eq!(oracle_round((fixed27(maxpos).unwrap() as i128).pow(2) * 2), maxpos);

    // Never-round-to-zero: minpos^2 = 2^-48 is below minpos but nonzero.
    let mut q = Q8::new();
    q.add_product(minpos, minpos);
    assert!(!q.is_zero());
    assert_eq!(q.to_bits(), minpos);

    // Exact cancellation does hit zero — the quire is exact.
    let mut q = Q8::new();
    q.add_product(0x35, 0x6B);
    q.sub_product(0x35, 0x6B);
    assert!(q.is_zero());
    assert_eq!(q.to_bits(), 0);

    // ...and cancellation of everything but one minpos^2 term still
    // renders minpos, not zero.
    let mut q = Q8::new();
    q.add_product(minpos, minpos);
    q.add_product(minpos, minpos);
    q.sub_product(minpos, minpos);
    assert_eq!(q.to_bits(), minpos);

    // NaR is absorbing: once poisoned, even zero products keep it NaR.
    let mut q = Q8::new();
    q.add_product(nar, one);
    q.add_product(0, 0);
    q.sub_product(one, one);
    assert!(q.is_nar());
    assert_eq!(q.to_bits(), nar);

    // Negative saturation mirrors positive.
    let neg_maxpos = SPEC.negate(maxpos);
    let mut q = Q8::new();
    q.add_product(neg_maxpos, maxpos);
    q.add_product(neg_maxpos, maxpos);
    assert_eq!(q.to_bits(), neg_maxpos);
}

#[test]
fn gquire32_matches_dedicated_posit32_quire() {
    // Two independent implementations of the same contract: the generic
    // GQuire<32,2> (decode/encode path) and the hand-rolled Posit(32,2)
    // Quire (unpack32/pack32 path) must agree bit-for-bit on every
    // accumulation, including wide-dynamic-range products and NaR.
    let mut rng = Pcg64::seed(0x8E2);
    let nar32 = 0x8000_0000u32;
    for case in 0..200 {
        let len = 1 + (rng.next_u64() % 24) as usize;
        let mut a = Vec::with_capacity(len);
        let mut b = Vec::with_capacity(len);
        for _ in 0..len {
            // Raw patterns: every u32 is a valid Posit(32,2) value.
            a.push(rng.next_u32());
            b.push(rng.next_u32());
        }
        if case % 17 == 0 {
            a[len / 2] = nar32; // NaR must propagate identically
        }
        assert_eq!(
            Quire::dot(&a, &b),
            GQuire::<32, 2>::dot(&a, &b),
            "case {case}"
        );
        // Stepwise agreement too (mixed add/sub, rounding at each step).
        let mut q = Quire::new();
        let mut g = GQuire::<32, 2>::new();
        for (i, (&x, &y)) in a.iter().zip(&b).enumerate() {
            if i % 3 == 2 {
                q.sub_product(x, y);
                g.sub_product(x, y);
            } else {
                q.add_product(x, y);
                g.add_product(x, y);
            }
            assert_eq!(q.to_posit_bits(), g.to_bits(), "case {case} step {i}");
            assert_eq!(q.is_nar(), g.is_nar(), "case {case} step {i}");
        }
    }
}
