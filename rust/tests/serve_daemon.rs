//! Serving-daemon contracts: deterministic backpressure, exactly-once
//! graceful drain, queue-depth worker scaling, bit-identical results vs
//! the sequential drivers, overload shedding, crash recovery over the
//! write-ahead journal, and the socket transports (Unix + TCP) end to
//! end.

use posit_accel::coordinator::NativeBackend;
use posit_accel::serve::{plan, Daemon, DaemonConfig, Priority};
use posit_accel::service::{
    mixed_format_manifest, run_job_sequential_any, EngineBuilder, JobResult, JobSpec, Precision,
};
use std::sync::Arc;

fn native_engine(max_batch: usize) -> posit_accel::service::Engine {
    EngineBuilder::new(max_batch)
        .shared("native", Arc::new(NativeBackend::new(1)))
        .build()
}

/// Small config tuned so tests exercise scaling and drain quickly.
fn test_config() -> DaemonConfig {
    DaemonConfig {
        queue_capacity: 64,
        min_workers: 1,
        max_workers: 4,
        retry_after_ms: 7,
        idle_exit_ms: 20,
        trace_interval_ms: 5,
        keep_factors: false,
        hold_workers: false,
        shed_low_on_full: true,
    }
}

/// A full admission queue must reject deterministically — same depth,
/// same hint, every time — and the held jobs must all complete exactly
/// once after release + drain.
#[test]
fn backpressure_rejects_deterministically_when_queue_full() {
    let config = DaemonConfig {
        queue_capacity: 2,
        hold_workers: true, // admit but don't run: the queue stays full
        keep_factors: false,
        ..test_config()
    };
    let daemon = Daemon::start(native_engine(8), config);
    let jobs = mixed_format_manifest(8, 32);
    // All 8 jobs are posit32/f32/f64-mixed; pick two of one format so they
    // land in the same shard and fill its queue.
    let posit_jobs: Vec<JobSpec> = jobs
        .iter()
        .filter(|j| j.precision == Precision::Posit32)
        .cloned()
        .collect();
    assert!(posit_jobs.len() >= 3, "need three same-shard jobs");

    assert!(daemon.submit(posit_jobs[0].clone(), Priority::Normal).is_ok());
    assert!(daemon.submit(posit_jobs[1].clone(), Priority::High).is_ok());
    assert_eq!(daemon.queue_depth(Precision::Posit32), 2);

    // Third submission hits the bound. The hint is a pure function of
    // (base=7, depth=2, capacity=2): 7 + 7*2/2 = 14 — and repeatable.
    for _ in 0..3 {
        let rej = daemon
            .submit(posit_jobs[2].clone(), Priority::Normal)
            .expect_err("queue is full, submission must reject");
        assert_eq!(rej.reason, "queue full");
        assert_eq!(rej.retry_after_ms, 14, "deterministic retry hint");
    }
    assert_eq!(daemon.rejected_count(), 3);
    assert_eq!(daemon.admitted_count(), 2, "rejected jobs are not admitted");

    // Release the hold; drain must finish exactly the two admitted jobs.
    daemon.release();
    let summary = daemon.drain();
    assert_eq!(summary.admitted, 2);
    assert_eq!(summary.completed, 2, "every admitted job completes");
    assert_eq!(summary.rejected, 3);
    let results = daemon.completed_results();
    assert_eq!(results.len(), 2, "no loss, no duplicates");
    let mut ids: Vec<usize> = results.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, vec![posit_jobs[0].id, posit_jobs[1].id]);
    assert_eq!(daemon.latency_samples().len(), 2, "one stats row per job");

    // Post-drain submissions reject with the "don't retry" hint.
    let rej = daemon
        .submit(posit_jobs[2].clone(), Priority::Normal)
        .expect_err("drained daemon admits nothing");
    assert_eq!(rej.reason, "draining");
    assert_eq!(rej.retry_after_ms, 0);
}

/// Drain racing a live submitter: every job admitted before the drain cut
/// completes exactly once (no loss, no duplicate stats rows), every job
/// rejected by the cut is dropped, and the two sets partition the stream.
#[test]
fn drain_mid_stream_completes_admitted_jobs_exactly_once() {
    let daemon = Daemon::start(native_engine(8), test_config());
    let jobs = mixed_format_manifest(24, 32);
    let submitter = {
        let daemon = daemon.clone();
        let jobs = jobs.clone();
        std::thread::spawn(move || {
            let mut admitted: Vec<usize> = Vec::new();
            let mut rejected: Vec<usize> = Vec::new();
            for spec in jobs {
                let id = spec.id;
                match daemon.submit(spec, Priority::Normal) {
                    Ok(_) => admitted.push(id),
                    Err(rej) => {
                        assert_eq!(rej.reason, "draining");
                        rejected.push(id);
                    }
                }
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
            (admitted, rejected)
        })
    };
    // Let some jobs through, then cut the stream mid-flight.
    std::thread::sleep(std::time::Duration::from_millis(8));
    let summary = daemon.drain();
    let (admitted, rejected) = submitter.join().unwrap();
    assert_eq!(admitted.len() + rejected.len(), jobs.len());
    assert!(!admitted.is_empty(), "some jobs were admitted before the cut");
    assert_eq!(summary.admitted, admitted.len());
    assert_eq!(summary.completed, admitted.len(), "drain finishes every admitted job");

    let results = daemon.completed_results();
    let mut result_ids: Vec<usize> = results.iter().map(|r| r.id).collect();
    result_ids.sort_unstable();
    let mut expect = admitted.clone();
    expect.sort_unstable();
    assert_eq!(result_ids, expect, "exactly the admitted set, once each");
    // Stats rows mirror results 1:1 (no duplicate accounting).
    let mut sample_ids: Vec<usize> =
        daemon.latency_samples().iter().map(|s| s.id).collect();
    sample_ids.sort_unstable();
    assert_eq!(sample_ids, expect);
}

/// The headline contract carried to the serving tier: a drained daemon
/// run over a fixed mixed-format job set — 4 concurrent submitters,
/// priorities drawn from the seeded plan — is bit-identical to the
/// sequential drivers on the same specs.
#[test]
fn drained_daemon_bit_identical_to_sequential_drivers() {
    let load = plan(10, 40, 11, 0.0, 4); // burst arrivals, 4 submitters
    let baseline: Vec<JobResult> = load
        .jobs
        .iter()
        .map(|(spec, _)| run_job_sequential_any(spec, &NativeBackend::new(1), true))
        .collect();
    for r in &baseline {
        assert!(r.error.is_none(), "baseline job {}: {:?}", r.id, r.error);
    }

    let config = DaemonConfig { keep_factors: true, ..test_config() };
    let daemon = Daemon::start(native_engine(8), config);
    std::thread::scope(|scope| {
        for s in 0..load.submitters {
            let daemon = daemon.clone();
            let load = &load;
            scope.spawn(move || {
                for i in (s..load.jobs.len()).step_by(load.submitters) {
                    let (spec, priority) = &load.jobs[i];
                    daemon.submit(spec.clone(), *priority).expect("capacity covers the burst");
                }
            });
        }
    });
    let summary = daemon.drain();
    assert_eq!(summary.admitted, load.jobs.len());
    assert_eq!(summary.completed, load.jobs.len());

    let results = daemon.completed_results(); // sorted by id
    assert_eq!(results.len(), baseline.len());
    for (seq, got) in baseline.iter().zip(&results) {
        assert_eq!(seq.id, got.id);
        assert!(got.error.is_none(), "daemon job {}", got.id);
        assert_eq!(
            seq.factors, got.factors,
            "daemon factors differ from sequential drivers: job {} ({})",
            seq.id,
            seq.precision.name()
        );
        assert_eq!(seq.ipiv, got.ipiv, "pivots differ: job {}", seq.id);
        assert_eq!(seq.fingerprint, got.fingerprint, "job {}", seq.id);
        assert_eq!(
            seq.backward_error.map(f64::to_bits),
            got.backward_error.map(f64::to_bits),
            "accuracy bits differ: job {}",
            seq.id
        );
        assert_eq!(seq.refine_iters, got.refine_iters, "job {}", seq.id);
    }

    // The bench artifact built from this run is well-formed and carries
    // the acceptance metrics.
    let json = daemon.bench_json(true, load.submitters, load.rate_jobs_per_s);
    for key in [
        "\"p50\"",
        "\"p95\"",
        "\"p99\"",
        "\"jobs_per_s\"",
        "\"queue_depth_trace\"",
        "\"per_format\"",
        "\"per_priority\"",
    ] {
        assert!(json.contains(key), "bench json missing {key}:\n{json}");
    }
    assert_eq!(
        json.matches('{').count(),
        json.matches('}').count(),
        "balanced braces"
    );
    // Per-job coordinator stats rolled up into the per-format rows: the
    // native-backend update phase ran for every shard, so at least one
    // rollup has positive update time and flops.
    assert!(json.contains("\"update_flops\""));
}

/// Worker pools scale against queue depth: a held shard keeps zero
/// workers (min 0), releasing a backlog spawns up to the cap, and the
/// drain leaves an accurate peak count.
#[test]
fn worker_pools_scale_with_queue_depth() {
    let config = DaemonConfig {
        min_workers: 0,
        max_workers: 2,
        hold_workers: true,
        ..test_config()
    };
    let daemon = Daemon::start(native_engine(8), config);
    let jobs: Vec<JobSpec> = mixed_format_manifest(15, 32)
        .into_iter()
        .filter(|j| j.precision == Precision::Posit32)
        .collect();
    assert!(jobs.len() >= 5);
    for spec in &jobs {
        daemon.submit(spec.clone(), Priority::Normal).unwrap();
    }
    assert_eq!(daemon.worker_count(Precision::Posit32), 0, "held shard stays at min");
    assert_eq!(daemon.queue_depth(Precision::Posit32), jobs.len());

    daemon.release();
    let summary = daemon.drain();
    assert_eq!(summary.completed, jobs.len());
    let peak = daemon.peak_workers(Precision::Posit32);
    assert!(
        (1..=2).contains(&peak),
        "scale-up bounded by max_workers: peak {peak}"
    );
    assert_eq!(daemon.peak_workers(Precision::F64), 0, "idle shard never scaled");
    assert_eq!(daemon.worker_count(Precision::Posit32), 0, "drain joins all workers");
}

/// Malformed-input corpus over the socket: every bad request line —
/// truncated objects, unknown ops and enum values (`accum=exact`),
/// duplicate keys, oversized lines and string fields, non-JSON noise —
/// gets one deterministic `op=error` reply (same bytes on every replay),
/// the connection stays up, and the daemon afterwards still serves pings
/// and runs real jobs end to end, `accum=quire` included.
#[cfg(unix)]
#[test]
fn malformed_corpus_gets_deterministic_errors_and_daemon_survives() {
    use posit_accel::serve::protocol::{
        get_bool, get_str, parse_flat_object, MAX_LINE_BYTES, MAX_STRING_BYTES,
    };
    use posit_accel::serve::serve_unix;
    use std::io::{BufRead, BufReader, Write};
    use std::os::unix::net::UnixStream;

    let dir = std::env::temp_dir();
    let pid = std::process::id();
    let socket = dir.join(format!("posit-serve-corpus-{pid}.sock"));
    let _ = std::fs::remove_file(&socket);

    let daemon = Daemon::start(native_engine(8), test_config());
    let server = {
        let socket = socket.clone();
        std::thread::spawn(move || serve_unix(daemon, &socket, None))
    };
    for _ in 0..200 {
        if socket.exists() {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    assert!(socket.exists(), "daemon never bound its socket");

    let corpus: Vec<String> = vec![
        "{".into(),                                         // truncated object
        "{\"op\": \"submit\", \"alg\": \"lu\"".into(),      // truncated mid-line
        "{\"op\": \"submit\", \"alg\": \"lu\", \"n\": ".into(), // truncated at value
        "not json at all".into(),
        "{\"op\": \"warp\"}".into(),                        // unknown op
        "{\"op\": \"submit\"}".into(),                      // missing alg/n
        "{\"op\": \"submit\", \"alg\": \"lu\", \"n\": -4}".into(),
        "{\"op\": \"submit\", \"alg\": \"lu\", \"n\": 16, \"accum\": \"exact\"}".into(),
        "{\"op\": \"submit\", \"alg\": \"lu\", \"n\": 16, \"precision\": \"f16\"}".into(),
        "{\"op\": \"submit\", \"alg\": \"lu\", \"n\": 16, \"accum\": \"quire\", \"accum\": \"rounded\"}".into(),
        "{\"op\": \"ping\", \"op\": \"shutdown\"}".into(),  // duplicate op must not drain
        "{\"op\": \"submit\", \"alg\": \"lu\", \"n\": 8, \"nested\": {\"x\": 1}}".into(),
        format!(
            "{{\"op\": \"submit\", \"alg\": \"lu\", \"n\": 8, \"backend\": \"{}\"}}",
            "x".repeat(MAX_STRING_BYTES + 1)
        ),
        format!("{{\"op\": \"ping\", \"pad\": {} }}", "9".repeat(MAX_LINE_BYTES)),
    ];

    let stream = UnixStream::connect(&socket).expect("connect");
    let mut writer = stream.try_clone().expect("clone");
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    let mut replies: Vec<Vec<String>> = Vec::new();
    for _round in 0..2 {
        let mut round_replies = Vec::new();
        for bad in &corpus {
            writeln!(writer, "{bad}").expect("send");
            line.clear();
            reader.read_line(&mut line).expect("reply");
            let fields = parse_flat_object(line.trim()).expect("error reply is flat");
            assert_eq!(get_str(&fields, "op"), Some("error"), "for {bad:.60}: {line}");
            assert_eq!(get_bool(&fields, "ok"), Some(false));
            round_replies.push(line.trim().to_string());
        }
        replies.push(round_replies);
    }
    assert_eq!(replies[0], replies[1], "error replies are deterministic");

    // A connection that dies mid-line must not take the daemon with it.
    {
        let mut partial = UnixStream::connect(&socket).expect("connect partial");
        partial.write_all(b"{\"op\": \"submit\", \"alg\":").expect("send partial");
        // Drop without a newline: the handler sees EOF on a half line.
    }

    // The daemon is intact: ping answers, real jobs still run — including
    // the quire accumulation path — and results carry the accum tag.
    line.clear();
    writeln!(writer, "{{\"op\": \"ping\"}}").unwrap();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("\"pong\""), "{line}");

    for submit in [
        "{\"op\": \"submit\", \"id\": 0, \"alg\": \"lu\", \"n\": 24, \"accum\": \"quire\"}",
        "{\"op\": \"submit\", \"id\": 1, \"alg\": \"lu\", \"n\": 24, \"accum\": \"rounded\"}",
    ] {
        line.clear();
        writeln!(writer, "{submit}").unwrap();
        reader.read_line(&mut line).unwrap();
        let fields = parse_flat_object(line.trim()).expect("flat reply");
        assert_eq!(get_str(&fields, "op"), Some("accepted"), "{line}");
    }
    line.clear();
    writeln!(writer, "{{\"op\": \"collect\", \"wait\": true}}").unwrap();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("\"count\": 2"), "{line}");
    assert!(line.contains("\"accum\": \"quire\""), "quire job tagged: {line}");
    assert!(line.contains("\"accum\": \"rounded\""), "rounded job tagged: {line}");
    assert!(!line.contains("\"error\": \"singular"), "{line}");

    line.clear();
    writeln!(writer, "{{\"op\": \"shutdown\"}}").unwrap();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("\"drained\""), "{line}");
    let summary = server.join().unwrap().expect("serve_unix");
    assert_eq!(summary.completed, 2);
    assert_eq!(summary.admitted, 2, "no malformed line was ever admitted");
}

/// Graceful degradation under overload: a full shard sheds its newest
/// strictly-lower-priority queued job to admit a higher-priority
/// arrival; the victim completes exactly once as a deterministic
/// `shed: ...` failure, peers never shed each other, and `--no-shed`
/// (shed_low_on_full: false) restores plain rejection.
#[test]
fn overload_sheds_lowest_priority_for_higher_priority_arrivals() {
    let config = DaemonConfig {
        queue_capacity: 2,
        hold_workers: true, // keep the queue full: nothing runs yet
        ..test_config()
    };
    let daemon = Daemon::start(native_engine(8), config);
    let jobs: Vec<JobSpec> = mixed_format_manifest(15, 24)
        .into_iter()
        .filter(|j| j.precision == Precision::Posit32)
        .collect();
    assert!(jobs.len() >= 4);

    daemon.submit(jobs[0].clone(), Priority::Low).unwrap();
    daemon.submit(jobs[1].clone(), Priority::Low).unwrap();
    assert_eq!(daemon.queue_depth(Precision::Posit32), 2);

    // A Low peer gets backpressure, not a shed — eviction never targets
    // an equal-or-higher lane.
    let rej = daemon.submit(jobs[2].clone(), Priority::Low).expect_err("peer must reject");
    assert_eq!(rej.reason, "queue full");
    assert_eq!(daemon.shed_count(), 0);

    // A High arrival evicts the NEWEST queued Low job (jobs[1]).
    let adm = daemon.submit(jobs[2].clone(), Priority::High).expect("shed admits High");
    assert_eq!(adm.queue_depth, 2, "victim freed the slot");
    assert_eq!(daemon.shed_count(), 1);
    assert_eq!(daemon.admitted_count(), 3);
    assert_eq!(daemon.completed_count(), 1, "the victim completed (as a failure)");
    let shed_rows: Vec<JobResult> = daemon
        .completed_results()
        .into_iter()
        .filter(|r| r.error.as_deref().is_some_and(|e| e.contains("shed")))
        .collect();
    assert_eq!(shed_rows.len(), 1);
    assert_eq!(shed_rows[0].id, jobs[1].id, "newest low-priority job is the victim");

    daemon.release();
    let summary = daemon.drain();
    assert_eq!(summary.admitted, 3);
    assert_eq!(summary.completed, 3, "survivors + victim, exactly once each");
    let results = daemon.completed_results();
    assert_eq!(results.len(), 3);
    let ran: Vec<&JobResult> = results.iter().filter(|r| r.error.is_none()).collect();
    assert_eq!(ran.len(), 2, "jobs[0] and the High job actually ran");
    assert!(daemon.stats_json().contains("\"shed\": 1"), "{}", daemon.stats_json());

    // With shedding disabled, the same pattern is a plain rejection.
    let config = DaemonConfig {
        queue_capacity: 2,
        hold_workers: true,
        shed_low_on_full: false,
        ..test_config()
    };
    let daemon = Daemon::start(native_engine(8), config);
    daemon.submit(jobs[0].clone(), Priority::Low).unwrap();
    daemon.submit(jobs[1].clone(), Priority::Low).unwrap();
    let rej = daemon.submit(jobs[2].clone(), Priority::High).expect_err("--no-shed rejects");
    assert_eq!(rej.reason, "queue full");
    assert_eq!(daemon.shed_count(), 0);
    daemon.release();
    daemon.drain();
}

/// The chaos contract: kill a journaled daemon mid-burst, restart on the
/// same journal, and every admitted job is collectible exactly once with
/// results bit-identical to an uninterrupted sequential run. Three
/// lives: (1) admit everything, crash before anything runs — the journal
/// holds only admits; (2) replay re-runs all jobs exactly once; (3) a
/// third life serves the full result set from the journal without
/// running anything.
#[test]
fn crash_recovery_replays_exactly_once_bit_identical() {
    use posit_accel::serve::{FsyncPolicy, Store};

    let journal = std::env::temp_dir()
        .join(format!("posit-serve-crash-{}.wal", std::process::id()));
    let _ = std::fs::remove_file(&journal);

    let load = plan(10, 40, 11, 0.0, 4);
    let baseline: Vec<JobResult> = load
        .jobs
        .iter()
        .map(|(spec, _)| run_job_sequential_any(spec, &NativeBackend::new(1), false))
        .collect();
    for r in &baseline {
        assert!(r.error.is_none(), "baseline job {}: {:?}", r.id, r.error);
    }

    // Life 1: admit the whole burst while the dispatch gate is held, then
    // die. To the journal this is the worst crash: acked admits, zero
    // results.
    let store = Store::open(&journal, FsyncPolicy::Never, false).expect("fresh journal");
    let config = DaemonConfig { hold_workers: true, ..test_config() };
    let (daemon, report) = Daemon::start_with_store(native_engine(8), config, store);
    assert_eq!((report.recovered_results, report.replayed_jobs), (0, 0));
    for (spec, priority) in &load.jobs {
        daemon.submit(spec.clone(), *priority).expect("capacity covers the burst");
    }
    assert_eq!(daemon.admitted_count(), load.jobs.len());
    daemon.abort();
    assert_eq!(daemon.completed_count(), 0, "nothing ran before the crash");

    // Life 2: replay. Every admitted-but-unfinished job re-runs exactly
    // once, bit-identical to the uninterrupted sequential run.
    let store = Store::open(&journal, FsyncPolicy::Never, false).expect("replay");
    assert!(!store.report.torn_tail, "a joined abort leaves whole records");
    let (daemon, report) = Daemon::start_with_store(native_engine(8), test_config(), store);
    assert_eq!(report.recovered_results, 0);
    assert_eq!(report.replayed_jobs, load.jobs.len());
    let summary = daemon.drain();
    assert_eq!(summary.admitted, load.jobs.len());
    assert_eq!(summary.completed, load.jobs.len(), "exactly once across the crash");
    let results = daemon.completed_results(); // sorted by id
    assert_eq!(results.len(), baseline.len(), "no loss, no duplicates");
    for (seq, got) in baseline.iter().zip(&results) {
        assert_eq!(seq.id, got.id);
        assert!(got.error.is_none(), "replayed job {}: {:?}", got.id, got.error);
        assert_eq!(seq.fingerprint, got.fingerprint, "job {}", seq.id);
        assert_eq!(
            seq.backward_error.map(f64::to_bits),
            got.backward_error.map(f64::to_bits),
            "accuracy bits differ after recovery: job {}",
            seq.id
        );
        assert_eq!(
            seq.digits.map(f64::to_bits),
            got.digits.map(f64::to_bits),
            "job {}",
            seq.id
        );
    }

    // Life 3: everything finished, so a restart serves the whole result
    // set from the journal without running a single job.
    let store = Store::open(&journal, FsyncPolicy::Never, false).expect("replay again");
    let (daemon, report) = Daemon::start_with_store(native_engine(8), test_config(), store);
    assert_eq!(report.recovered_results, load.jobs.len());
    assert_eq!(report.replayed_jobs, 0);
    assert_eq!(daemon.recovered_results(), load.jobs.len());
    let results = daemon.completed_results();
    assert_eq!(results.len(), baseline.len());
    for (seq, got) in baseline.iter().zip(&results) {
        assert_eq!(seq.fingerprint, got.fingerprint, "recovered job {}", seq.id);
        assert_eq!(
            seq.digits.map(f64::to_bits),
            got.digits.map(f64::to_bits),
            "recovered digits round-trip bitwise: job {}",
            seq.id
        );
    }
    let summary = daemon.drain();
    assert_eq!(summary.admitted, load.jobs.len(), "recovered jobs count as admitted");
    assert_eq!(summary.completed, load.jobs.len());
    let _ = std::fs::remove_file(&journal);
}

/// Malformed-journal corpus at the store level: interior corruption
/// fails loudly (naming `--repair`), `--repair` skips the bad record and
/// keeps the intact ones, and a torn trailing record is silently
/// truncated — after which the reopened journal appends cleanly.
#[test]
fn corrupt_journal_fails_loudly_and_torn_tail_truncates() {
    use posit_accel::serve::{FsyncPolicy, Journal, Store};

    let pid = std::process::id();
    let jobs = mixed_format_manifest(2, 24);

    // Interior corruption: flip one byte inside the first of two records.
    let path = std::env::temp_dir().join(format!("posit-serve-corrupt-{pid}.wal"));
    let _ = std::fs::remove_file(&path);
    {
        let j = Journal::open(&path, FsyncPolicy::Never).unwrap();
        j.append_admit(&jobs[0], Priority::Normal).unwrap();
        j.append_admit(&jobs[1], Priority::Low).unwrap();
    }
    let mut bytes = std::fs::read(&path).unwrap();
    bytes[20] ^= 0x01;
    std::fs::write(&path, &bytes).unwrap();
    let err = Store::open(&path, FsyncPolicy::Never, false)
        .expect_err("interior corruption must fail loudly");
    let msg = format!("{err:#}");
    assert!(msg.contains("--repair"), "error names the escape hatch: {msg}");
    let store = Store::open(&path, FsyncPolicy::Never, true).expect("--repair opens");
    assert_eq!(store.report.skipped, 1, "one corrupt record skipped");
    assert_eq!(store.pending.len(), 1, "the intact admit survives");
    assert_eq!(store.pending[0].0.id, jobs[1].id);
    let _ = std::fs::remove_file(&path);

    // Torn tail: chop the final record mid-line (a crash mid-write).
    let path = std::env::temp_dir().join(format!("posit-serve-torn-{pid}.wal"));
    let _ = std::fs::remove_file(&path);
    {
        let j = Journal::open(&path, FsyncPolicy::Never).unwrap();
        j.append_admit(&jobs[0], Priority::Normal).unwrap();
        j.append_admit(&jobs[1], Priority::Low).unwrap();
    }
    let bytes = std::fs::read(&path).unwrap();
    std::fs::write(&path, &bytes[..bytes.len() - 7]).unwrap();
    let store = Store::open(&path, FsyncPolicy::Never, false).expect("torn tail is tolerated");
    assert!(store.report.torn_tail);
    assert_eq!(store.pending.len(), 1, "only the whole record replays");
    assert!(
        std::fs::metadata(&path).unwrap().len() < (bytes.len() - 7) as u64,
        "the torn bytes are physically truncated"
    );
    // The reopened journal appends cleanly after the truncation.
    store.journal.append_admit(&jobs[1], Priority::High).unwrap();
    drop(store);
    let store = Store::open(&path, FsyncPolicy::Never, false).expect("clean replay");
    assert!(!store.report.torn_tail);
    assert_eq!(store.pending.len(), 2);
    let _ = std::fs::remove_file(&path);
}

/// End-to-end over TCP: the same protocol, daemon, and graceful drain as
/// the Unix transport, reached through `Listen::Tcp` — submit, collect,
/// shutdown on one persistent connection.
#[cfg(unix)]
#[test]
fn tcp_daemon_end_to_end() {
    use posit_accel::serve::protocol::{get_num, get_str, parse_flat_object, submit_line};
    use posit_accel::serve::{serve, Listen};
    use std::io::{BufRead, BufReader, Write};

    // Reserve an OS-assigned port, then hand it to the daemon. (The
    // listener is dropped before the daemon binds; the race window is
    // acceptable for a test.)
    let port = {
        let probe = std::net::TcpListener::bind("127.0.0.1:0").expect("probe bind");
        probe.local_addr().unwrap().port()
    };
    let listen = Listen::Tcp(format!("127.0.0.1:{port}"));

    let daemon = Daemon::start(native_engine(8), test_config());
    let server = {
        let listen = listen.clone();
        std::thread::spawn(move || serve(daemon, &listen, None))
    };

    // Wait for the daemon to bind.
    let mut conn = None;
    for _ in 0..400 {
        match listen.connect() {
            Ok(c) => {
                conn = Some(c);
                break;
            }
            Err(_) => std::thread::sleep(std::time::Duration::from_millis(5)),
        }
    }
    let stream = conn.expect("daemon never bound its TCP port");
    let mut writer = stream.try_clone().expect("clone");
    let mut reader = BufReader::new(stream);
    let mut line = String::new();

    writeln!(writer, "{{\"op\": \"ping\"}}").unwrap();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("\"pong\""), "{line}");

    let jobs = mixed_format_manifest(4, 24);
    for spec in &jobs {
        line.clear();
        writeln!(writer, "{}", submit_line(spec, Priority::Normal)).expect("send");
        reader.read_line(&mut line).expect("reply");
        let fields = parse_flat_object(line.trim()).expect("flat reply");
        assert_eq!(get_str(&fields, "op"), Some("accepted"), "{line}");
    }

    line.clear();
    writeln!(writer, "{{\"op\": \"collect\", \"wait\": true}}").unwrap();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains(&format!("\"count\": {}", jobs.len())), "{line}");

    line.clear();
    writeln!(writer, "{{\"op\": \"shutdown\"}}").unwrap();
    reader.read_line(&mut line).unwrap();
    let fields = parse_flat_object(line.trim()).expect("drained reply is flat");
    assert_eq!(get_str(&fields, "op"), Some("drained"), "{line}");
    assert_eq!(get_num(&fields, "admitted"), Some(jobs.len() as f64));
    assert_eq!(get_num(&fields, "completed"), Some(jobs.len() as f64));
    let summary = server.join().unwrap().expect("serve over tcp");
    assert_eq!(summary.completed, jobs.len());
}

/// End-to-end over the Unix socket: 4 concurrent submitter connections
/// stream the open-loop plan with retry-on-backpressure, a control
/// connection collects and shuts down, and the daemon writes a
/// well-formed bench artifact.
#[cfg(unix)]
#[test]
fn socket_daemon_end_to_end() {
    use posit_accel::serve::protocol::{
        get_bool, get_num, get_str, parse_flat_object, submit_line,
    };
    use posit_accel::serve::serve_unix;
    use std::io::{BufRead, BufReader, Write};
    use std::os::unix::net::UnixStream;

    let dir = std::env::temp_dir();
    let pid = std::process::id();
    let socket = dir.join(format!("posit-serve-test-{pid}.sock"));
    let bench = dir.join(format!("posit-serve-test-{pid}.json"));
    let _ = std::fs::remove_file(&socket);
    let _ = std::fs::remove_file(&bench);

    let daemon = Daemon::start(native_engine(8), test_config());
    let server = {
        let socket = socket.clone();
        let bench = bench.clone();
        std::thread::spawn(move || serve_unix(daemon, &socket, Some(&bench)))
    };
    // Wait for the daemon to bind.
    for _ in 0..200 {
        if socket.exists() {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    assert!(socket.exists(), "daemon never bound its socket");

    let load = plan(12, 32, 3, 200.0, 4);
    std::thread::scope(|scope| {
        for s in 0..load.submitters {
            let load = &load;
            let socket = &socket;
            scope.spawn(move || {
                let stream = UnixStream::connect(socket).expect("connect");
                let mut writer = stream.try_clone().expect("clone");
                let mut reader = BufReader::new(stream);
                let mut line = String::new();
                for i in (s..load.jobs.len()).step_by(load.submitters) {
                    let (spec, priority) = &load.jobs[i];
                    loop {
                        writeln!(writer, "{}", submit_line(spec, *priority)).expect("send");
                        line.clear();
                        reader.read_line(&mut line).expect("reply");
                        let fields = parse_flat_object(line.trim()).expect("flat reply");
                        match get_str(&fields, "op") {
                            Some("accepted") => break,
                            Some("rejected") => {
                                let hint =
                                    get_num(&fields, "retry_after_ms").unwrap_or(0.0) as u64;
                                assert!(hint > 0, "live daemon must offer a retry");
                                std::thread::sleep(std::time::Duration::from_millis(hint));
                            }
                            other => panic!("unexpected reply {other:?}: {line}"),
                        }
                    }
                }
            });
        }
    });

    // Control connection: ping, settle, then drain.
    let stream = UnixStream::connect(&socket).expect("connect control");
    let mut writer = stream.try_clone().expect("clone");
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    writeln!(writer, "{{\"op\": \"ping\"}}").unwrap();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("\"pong\""), "{line}");

    line.clear();
    writeln!(writer, "{{\"op\": \"collect\", \"wait\": true}}").unwrap();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains(&format!("\"count\": {}", load.jobs.len())), "{line}");

    line.clear();
    writeln!(writer, "{{\"op\": \"shutdown\", \"submitters\": 4, \"rate_jobs_per_s\": 200}}")
        .unwrap();
    reader.read_line(&mut line).unwrap();
    let fields = parse_flat_object(line.trim()).expect("drained reply is flat");
    assert_eq!(get_str(&fields, "op"), Some("drained"), "{line}");
    assert_eq!(get_bool(&fields, "ok"), Some(true));
    assert_eq!(get_num(&fields, "admitted"), Some(load.jobs.len() as f64));
    assert_eq!(get_num(&fields, "completed"), Some(load.jobs.len() as f64));

    let summary = server.join().unwrap().expect("serve_unix");
    assert_eq!(summary.completed, load.jobs.len());
    assert!(!socket.exists(), "socket file removed after drain");

    let json = std::fs::read_to_string(&bench).expect("bench artifact written");
    for key in ["\"p50\"", "\"p95\"", "\"p99\"", "\"jobs_per_s\"", "\"queue_depth_trace\""] {
        assert!(json.contains(key), "bench json missing {key}");
    }
    assert!(json.contains("\"submitters\": 4"), "shutdown metadata recorded");
    assert_eq!(json.matches('{').count(), json.matches('}').count());
    let _ = std::fs::remove_file(&bench);
}
