//! Bit-identity of the decode-once packed GEMM microkernel.
//!
//! The contract (DESIGN §7 / README rounding contract): `gemm_packed` —
//! and therefore `gemm`, `gemm_parallel` and every backend routed through
//! them — produces results **bit-identical** to the `gemm_naive` ground
//! truth for every format, every transpose combination, odd shapes and
//! over-allocated leading dimensions.
//!
//! The Posit(8,2) sweep is exhaustive in the operand values: the packed
//! tiles are constructed so that every 8-bit pattern (zero, NaR, both
//! signs, every regime) appears in op(A) and op(B), and every ordered
//! operand *pair* occurs in some inner product — the same closure style
//! as the 256×256 scalar-op sweeps in `posit8_exhaustive.rs`, but through
//! the whole GEMM stack (pack, microkernel, unpacked mac, re-encode).

use posit_accel::blas::{gemm, gemm_naive, gemm_packed, gemm_packed_lanes, Scalar, Trans};
use posit_accel::posit::formats::{P16, P8};
use posit_accel::posit::Posit32;
use posit_accel::rng::Pcg64;

const NAR8: P8 = P8(0x80);

/// Column-major buffer with `ld > rows`: padding rows hold `sentinel` (a
/// poison value — the kernels must neither read nor write them).
fn strided<T: Scalar>(
    rows: usize,
    cols: usize,
    ld: usize,
    mut gen: impl FnMut(usize, usize) -> T,
    sentinel: T,
) -> Vec<T> {
    assert!(ld >= rows);
    let mut v = vec![sentinel; ld * cols.max(1)];
    for j in 0..cols {
        for i in 0..rows {
            v[i + j * ld] = gen(i, j);
        }
    }
    v
}

fn bits_of<T: Scalar>(v: &[T]) -> Vec<u64> {
    v.iter().map(|x| x.bits()).collect()
}

/// Exhaustive Posit(8,2) value/pair coverage through the full GEMM stack.
#[test]
fn p8_exhaustive_pattern_sweep_packed_vs_naive() {
    // A (5 x 256): every row walks all 256 bit patterns; B (256 x 256):
    // the (x, y) operand pair occurs at (l = x, j = y - 5x mod 256) in
    // row 0's inner products. Odd m, ld > rows on every operand.
    let (m, k, n) = (5usize, 256usize, 256usize);
    let (lda, ldb, ldc) = (m + 2, k + 1, m + 3);
    let a = strided(m, k, lda, |i, l| P8(((l + 3 * i) & 255) as u32), NAR8);
    let b = strided(k, n, ldb, |l, j| P8(((5 * l + j) & 255) as u32), NAR8);
    for (alpha, beta) in [(1.0, 0.0), (-2.0, 1.0), (0.5, -0.25)] {
        let al = P8::from_f64(alpha);
        let be = P8::from_f64(beta);
        let c0 = strided(
            m,
            n,
            ldc,
            |i, j| P8::from_f64(((i * 7 + j) % 5) as f64 - 2.0),
            NAR8,
        );
        let mut c1 = c0.clone();
        let mut c2 = c0.clone();
        gemm_naive(
            Trans::No,
            Trans::No,
            m,
            n,
            k,
            al,
            &a,
            lda,
            &b,
            ldb,
            be,
            &mut c1,
            ldc,
        );
        gemm_packed(
            Trans::No,
            Trans::No,
            m,
            n,
            k,
            al,
            &a,
            lda,
            &b,
            ldb,
            be,
            &mut c2,
            ldc,
        );
        assert_eq!(bits_of(&c1), bits_of(&c2), "alpha {alpha} beta {beta}");
        // Padding rows of C must be untouched by the packed writeback.
        for j in 0..n {
            for i in m..ldc {
                assert_eq!(c2[i + j * ldc], NAR8, "padding clobbered at ({i},{j})");
            }
        }
        // The same exhaustive pattern/pair closure through the
        // lane-parallel (SIMD) microkernel body, whatever the build's
        // `simd` feature state.
        let mut c3 = c0.clone();
        gemm_packed_lanes(
            Trans::No,
            Trans::No,
            m,
            n,
            k,
            al,
            &a,
            lda,
            &b,
            ldb,
            be,
            &mut c3,
            ldc,
        );
        assert_eq!(bits_of(&c1), bits_of(&c3), "lanes alpha {alpha} beta {beta}");
    }
}

/// Random Posit(8,2) tiles (all 256 patterns equally likely, so zero/NaR
/// and every regime keep appearing): all four transpose combinations, odd
/// m/n/k, leading dimensions strictly greater than the operand rows.
#[test]
fn p8_random_tiles_all_transposes_odd_dims_strided() {
    let mut rng = Pcg64::seed(0x8888);
    for &(m, n, k) in &[(13usize, 11usize, 17usize), (7, 5, 9), (21, 3, 25), (3, 19, 7)] {
        for ta in [Trans::No, Trans::Yes] {
            for tb in [Trans::No, Trans::Yes] {
                let (ar, ac) = if ta == Trans::No { (m, k) } else { (k, m) };
                let (br, bc) = if tb == Trans::No { (k, n) } else { (n, k) };
                let (lda, ldb, ldc) = (ar + 3, br + 1, m + 2);
                let a = strided(ar, ac, lda, |_, _| P8(rng.next_u32() & 255), NAR8);
                let b = strided(br, bc, ldb, |_, _| P8(rng.next_u32() & 255), NAR8);
                let c0 = strided(m, n, ldc, |_, _| P8(rng.next_u32() & 255), NAR8);
                let al = P8::from_f64(1.0);
                let be = P8::from_f64(1.0);
                let mut c1 = c0.clone();
                let mut c2 = c0.clone();
                let mut c3 = c0.clone();
                gemm_naive(ta, tb, m, n, k, al, &a, lda, &b, ldb, be, &mut c1, ldc);
                gemm_packed(ta, tb, m, n, k, al, &a, lda, &b, ldb, be, &mut c2, ldc);
                gemm(ta, tb, m, n, k, al, &a, lda, &b, ldb, be, &mut c3, ldc);
                assert_eq!(bits_of(&c1), bits_of(&c2), "packed {m}x{n}x{k} {ta:?}{tb:?}");
                assert_eq!(bits_of(&c1), bits_of(&c3), "routed {m}x{n}x{k} {ta:?}{tb:?}");
            }
        }
    }
}

/// Posit32 across the whole dynamic range (scales from 2^-100 to 2^100,
/// where regimes are long and the saturation slow path engages), plus
/// sprinkled zeros and NaR — every transpose combination, strided.
#[test]
fn posit32_wide_range_tiles_packed_vs_naive_all_transposes() {
    let mut rng = Pcg64::seed(0x3232);
    let val = |rng: &mut Pcg64| -> Posit32 {
        match rng.next_u32() % 16 {
            0 => Posit32::ZERO,
            1 => Posit32::NAR,
            2..=5 => Posit32::from_f64(rng.normal()),
            6..=9 => {
                let e = (rng.next_u32() % 200) as i32 - 100;
                Posit32::from_f64(rng.normal() * 2f64.powi(e))
            }
            _ => Posit32(rng.next_u32()),
        }
    };
    for &(m, n, k) in &[(33usize, 29usize, 41usize), (17, 9, 5)] {
        for ta in [Trans::No, Trans::Yes] {
            for tb in [Trans::No, Trans::Yes] {
                let (ar, ac) = if ta == Trans::No { (m, k) } else { (k, m) };
                let (br, bc) = if tb == Trans::No { (k, n) } else { (n, k) };
                let (lda, ldb, ldc) = (ar + 2, br + 5, m + 1);
                let a = strided(ar, ac, lda, |_, _| val(&mut rng), Posit32::NAR);
                let b = strided(br, bc, ldb, |_, _| val(&mut rng), Posit32::NAR);
                let c0 = strided(m, n, ldc, |_, _| val(&mut rng), Posit32::NAR);
                let al = Posit32::from_f64(-1.0);
                let be = Posit32::ONE;
                let mut c1 = c0.clone();
                let mut c2 = c0.clone();
                let mut c3 = c0.clone();
                gemm_naive(ta, tb, m, n, k, al, &a, lda, &b, ldb, be, &mut c1, ldc);
                gemm_packed(ta, tb, m, n, k, al, &a, lda, &b, ldb, be, &mut c2, ldc);
                gemm_packed_lanes(ta, tb, m, n, k, al, &a, lda, &b, ldb, be, &mut c3, ldc);
                assert_eq!(bits_of(&c1), bits_of(&c2), "{m}x{n}x{k} {ta:?}{tb:?}");
                assert_eq!(bits_of(&c1), bits_of(&c3), "lanes {m}x{n}x{k} {ta:?}{tb:?}");
            }
        }
    }
}

/// The other formats ride the same packed kernel: P<16,1> through the
/// generic engine, f32/f64 through the trivial passthrough planes.
#[test]
fn p16_f32_f64_packed_vs_naive() {
    let mut rng = Pcg64::seed(0x1616);
    let (m, n, k) = (19usize, 15usize, 21usize);
    // P<16,1>
    {
        let a = strided(m, k, m + 1, |_, _| P16(rng.next_u32() & 0xFFFF), P16(0x8000));
        let b = strided(k, n, k + 2, |_, _| P16(rng.next_u32() & 0xFFFF), P16(0x8000));
        let c0 = strided(m, n, m + 3, |_, _| P16(rng.next_u32() & 0xFFFF), P16(0x8000));
        let mut c1 = c0.clone();
        let mut c2 = c0.clone();
        let one = P16::from_f64(1.0);
        gemm_naive(Trans::No, Trans::No, m, n, k, one, &a, m + 1, &b, k + 2, one, &mut c1, m + 3);
        gemm_packed(Trans::No, Trans::No, m, n, k, one, &a, m + 1, &b, k + 2, one, &mut c2, m + 3);
        assert_eq!(bits_of(&c1), bits_of(&c2), "P<16,1>");
    }
    // f32 / f64 (NaN-free tiles; IEEE passthrough planes).
    {
        // op(A) = A^T with A of shape (k, m).
        let a = strided(k, m, k + 1, |_, _| rng.normal() as f32, 0.0f32);
        let b = strided(k, n, k + 2, |_, _| rng.normal() as f32, 0.0f32);
        let c0 = strided(m, n, m + 3, |_, _| rng.normal() as f32, 0.0f32);
        let mut c1 = c0.clone();
        let mut c2 = c0.clone();
        gemm_naive(Trans::Yes, Trans::No, m, n, k, 2.0f32, &a, k + 1, &b, k + 2, 0.0, &mut c1, m + 3);
        gemm_packed(Trans::Yes, Trans::No, m, n, k, 2.0f32, &a, k + 1, &b, k + 2, 0.0, &mut c2, m + 3);
        assert_eq!(bits_of(&c1), bits_of(&c2), "f32");
    }
    {
        // op(B) = B^T with B of shape (n, k).
        let a = strided(m, k, m + 4, |_, _| rng.normal(), 0.0f64);
        let b = strided(n, k, n + 1, |_, _| rng.normal(), 0.0f64);
        let c0 = strided(m, n, m + 2, |_, _| rng.normal(), 0.0f64);
        let mut c1 = c0.clone();
        let mut c2 = c0.clone();
        gemm_naive(Trans::No, Trans::Yes, m, n, k, 1.0f64, &a, m + 4, &b, n + 1, 0.5, &mut c1, m + 2);
        gemm_packed(Trans::No, Trans::Yes, m, n, k, 1.0f64, &a, m + 4, &b, n + 1, 0.5, &mut c2, m + 2);
        assert_eq!(bits_of(&c1), bits_of(&c2), "f64");
    }
}
