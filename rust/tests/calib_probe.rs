// temp calibration probe
#[test]
fn calib_probe() {
    use posit_accel::posit::counting::*;
    use posit_accel::posit::generic::PositSpec;
    use posit_accel::rng::Pcg64;
    let spec = PositSpec::P32;
    let mut rng = Pcg64::seed(1);
    for (i, r) in PAPER_RANGES.iter().enumerate() {
        for op in PositOp::ALL {
            let s = profile_op(spec, op, *r, 64, &mut rng);
            println!("I{} {:?}: n_inst={:.0} n_cont={:.0} f_branch={:.3} warp={:.0}", i, op, s.n_inst, s.n_cont, s.f_branch, s.warp_inst);
        }
    }
    for sigma in [1e-2, 1.0, 1e2, 1e4, 1e6] {
        let s = profile_gemm_fma(spec, sigma, 24, 16, &mut rng);
        println!("fma sigma={sigma:.0e}: n_inst={:.0} warp={:.0} fb={:.3}", s.n_inst, s.warp_inst, s.f_branch);
    }
}
