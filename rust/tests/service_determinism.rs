//! Service-layer determinism: the headline contract of `crate::service`.
//!
//! The same job manifest is run with 1, 4 and 8 workers on a shared
//! `NativeBackend` and a shared `TimedBackend`-modelled accelerator, and
//! every factor matrix, pivot vector and fingerprint must be bit-identical
//! to the sequential `*_offload` drivers on the same specs. Scheduling —
//! worker count, batch folding, pool interleaving — must never leak into
//! the numerics.
//!
//! The mixed-format tests extend the contract across the format-generic
//! API: one manifest carrying posit32 + f32 + f64 jobs (including
//! `mode=refine` mixed-precision jobs) must be bit-identical to the
//! sequential drivers *per format* at any worker count.
//!
//! The mixed-accum tests extend it across the per-job `accum` knob: a
//! manifest mixing `accum=rounded` and `accum=quire` jobs must be
//! bit-identical to the sequential drivers at any worker count, and the
//! quire GEMM path itself must equal an element-by-element
//! one-rounding-per-output reference built directly on the [`Quire`].

use posit_accel::blas::{gemm_naive, gemm_update_quire, Accum, Scalar, Trans};
use posit_accel::coordinator::{GemmBackend, NativeBackend, TimedBackend};
use posit_accel::posit::quire::Quire;
use posit_accel::posit::Posit32;
use posit_accel::rng::Pcg64;
use posit_accel::service::{
    mixed_accum_manifest, mixed_format_manifest, mixed_manifest, run_job_sequential,
    run_job_sequential_any, Engine, EngineBuilder, JobResult, Mode, Precision,
};
use std::sync::Arc;

/// A backend that applies every update with the *reference* `gemm_naive`
/// kernel — the pre-packing GEMM semantics in their simplest form. The
/// engine's `NativeBackend` (now routed through `gemm_packed`) must
/// reproduce it bit-for-bit: rewiring the backends through the packed
/// microkernel must not change a single job output.
struct NaiveRefBackend;

impl<T: Scalar> GemmBackend<T> for NaiveRefBackend {
    fn name(&self) -> &str {
        "naive-ref"
    }
    fn gemm_update(
        &self,
        m: usize,
        k: usize,
        n: usize,
        a: &[T],
        lda: usize,
        b: &[T],
        ldb: usize,
        c: &mut [T],
        ldc: usize,
    ) -> anyhow::Result<()> {
        let minus1 = T::one().neg();
        gemm_naive(
            Trans::No,
            Trans::No,
            m,
            n,
            k,
            minus1,
            a,
            lda,
            b,
            ldb,
            T::one(),
            c,
            ldc,
        );
        Ok(())
    }
}

fn shared_backends() -> Vec<(&'static str, Arc<dyn GemmBackend>)> {
    vec![
        (
            "native",
            Arc::new(NativeBackend::new(2)) as Arc<dyn GemmBackend>,
        ),
        (
            "timed-fpga",
            Arc::new(TimedBackend::new(
                "timed-fpga",
                NativeBackend::new(2),
                // Toy cost model; the value is irrelevant to the contract.
                |m, k, n| (2 * m * k * n) as f64 / 200e9,
            )) as Arc<dyn GemmBackend>,
        ),
    ]
}

#[test]
fn factors_bit_identical_across_worker_counts_and_backends() {
    let jobs = mixed_manifest(10, 48);
    for (name, backend) in shared_backends() {
        // Ground truth: the plain sequential drivers, job by job.
        let baseline: Vec<JobResult> = jobs
            .iter()
            .map(|spec| run_job_sequential(spec, backend.as_ref(), true))
            .collect();
        for spec_result in &baseline {
            assert!(
                spec_result.error.is_none(),
                "baseline {name} job {}: {:?}",
                spec_result.id,
                spec_result.error
            );
        }
        for workers in [1usize, 4, 8] {
            let engine = Engine::new(vec![(name.to_string(), Arc::clone(&backend))], 8);
            let report = engine.run(&jobs, workers, true);
            assert_eq!(report.results.len(), jobs.len());
            for (seq, got) in baseline.iter().zip(&report.results) {
                assert_eq!(seq.id, got.id);
                assert!(got.error.is_none(), "{name} x{workers} job {}", got.id);
                assert_eq!(
                    seq.factors, got.factors,
                    "factors differ: {name} x{workers} job {}",
                    seq.id
                );
                assert_eq!(
                    seq.ipiv, got.ipiv,
                    "pivots differ: {name} x{workers} job {}",
                    seq.id
                );
                assert_eq!(seq.fingerprint, got.fingerprint);
                // The modelled accelerator seconds are part of the
                // deterministic contract too (pure function of the tile
                // shapes), unlike wall-clock phase timings.
                assert!(
                    (seq.stats.simulated_s - got.stats.simulated_s).abs() <= 1e-12,
                    "{name} x{workers} job {}: simulated {} vs {}",
                    seq.id,
                    seq.stats.simulated_s,
                    got.stats.simulated_s
                );
            }
        }
    }
}

/// Mixed-format determinism: one manifest carrying posit32 + f32 + f64
/// jobs (factorize and refine modes) through a shared format-transparent
/// backend must be bit-identical to the sequential drivers per format at
/// any worker count.
fn assert_mixed_manifest_deterministic<B>(name: &str, backend: Arc<B>)
where
    B: GemmBackend<posit_accel::posit::Posit32>
        + GemmBackend<f32>
        + GemmBackend<f64>
        + 'static,
{
    let mut jobs = mixed_format_manifest(12, 48);
    // The generator marks posit32 refine jobs (ids 3, 10); add an f32 and
    // an f64 refinement job so every format exercises the refine path.
    jobs[4].mode = Mode::Refine; // id 4: f32
    jobs[7].mode = Mode::Refine; // id 7: f64
    for p in Precision::ALL {
        assert!(jobs.iter().any(|j| j.precision == p), "manifest must mix formats");
    }
    assert!(jobs.iter().any(|j| j.mode == Mode::Refine && j.precision == Precision::F32));

    // Ground truth: the plain sequential drivers, job by job, format picked
    // from the spec.
    let baseline: Vec<JobResult> = jobs
        .iter()
        .map(|spec| run_job_sequential_any(spec, &*backend, true))
        .collect();
    for r in &baseline {
        assert!(r.error.is_none(), "baseline {name} job {}: {:?}", r.id, r.error);
    }

    for workers in [1usize, 4, 8] {
        let engine = EngineBuilder::new(8).shared(name, Arc::clone(&backend)).build();
        let report = engine.run(&jobs, workers, true);
        assert_eq!(report.results.len(), jobs.len());
        for (seq, got) in baseline.iter().zip(&report.results) {
            assert_eq!(seq.id, got.id);
            assert!(got.error.is_none(), "{name} x{workers} job {}", got.id);
            assert_eq!(got.precision, jobs[got.id].precision);
            assert_eq!(
                seq.factors, got.factors,
                "factors/solution differ: {name} x{workers} job {} ({})",
                seq.id,
                seq.precision.name()
            );
            assert_eq!(seq.ipiv, got.ipiv, "pivots differ: {name} x{workers} job {}", seq.id);
            assert_eq!(seq.fingerprint, got.fingerprint);
            // The accuracy numbers are pure functions of the factors — part
            // of the bit-determinism contract (compared as bits, not ≈).
            assert_eq!(
                seq.backward_error.map(f64::to_bits),
                got.backward_error.map(f64::to_bits),
                "{name} x{workers} job {}",
                seq.id
            );
            assert_eq!(seq.refine_iters, got.refine_iters);
            assert!(
                (seq.stats.simulated_s - got.stats.simulated_s).abs() <= 1e-12,
                "{name} x{workers} job {}: simulated {} vs {}",
                seq.id,
                seq.stats.simulated_s,
                got.stats.simulated_s
            );
        }
        // Tiles must have flowed through every format's own queue.
        for fmt in ["posit32", "binary32", "binary64"] {
            let q = report.queues.iter().find(|q| q.format == fmt).unwrap();
            assert!(q.tiles > 0, "{name} x{workers}: {fmt} queue saw no tiles");
        }
    }
}

#[test]
fn mixed_format_manifest_bit_identical_across_worker_counts() {
    assert_mixed_manifest_deterministic("native", Arc::new(NativeBackend::new(2)));
}

#[test]
fn mixed_format_manifest_bit_identical_on_modelled_accelerator() {
    assert_mixed_manifest_deterministic(
        "timed-fpga",
        Arc::new(TimedBackend::new(
            "timed-fpga",
            NativeBackend::new(2),
            |m, k, n| (2 * m * k * n) as f64 / 200e9,
        )),
    );
}

/// PR-4 guard: the whole engine — NativeBackend routed through the
/// decode-once packed GEMM, batched dispatch, any worker count — must be
/// bit-identical to the sequential drivers running on the naive reference
/// kernel, i.e. to the pre-packing semantics. Covers every format and
/// both factor and refine modes.
#[test]
fn packed_engine_matches_pre_packing_naive_semantics() {
    // Posit32 manifest.
    let jobs = mixed_manifest(8, 48);
    let baseline: Vec<JobResult> = jobs
        .iter()
        .map(|spec| {
            run_job_sequential::<posit_accel::posit::Posit32>(spec, &NaiveRefBackend, true)
        })
        .collect();
    for r in &baseline {
        assert!(r.error.is_none(), "naive baseline job {}: {:?}", r.id, r.error);
    }
    for workers in [1usize, 4] {
        let engine = Engine::new(
            vec![(
                "native".to_string(),
                Arc::new(NativeBackend::new(2)) as Arc<dyn GemmBackend>,
            )],
            8,
        );
        let report = engine.run(&jobs, workers, true);
        for (seq, got) in baseline.iter().zip(&report.results) {
            assert!(got.error.is_none(), "x{workers} job {}", got.id);
            assert_eq!(
                seq.factors, got.factors,
                "packed engine factors differ from naive drivers: x{workers} job {}",
                seq.id
            );
            assert_eq!(seq.ipiv, got.ipiv, "x{workers} job {}", seq.id);
            assert_eq!(seq.fingerprint, got.fingerprint, "x{workers} job {}", seq.id);
        }
    }

    // Mixed-format manifest (posit32 + f32 + f64, refine included).
    let mut mjobs = mixed_format_manifest(9, 40);
    mjobs[4].mode = Mode::Refine;
    let baseline: Vec<JobResult> = mjobs
        .iter()
        .map(|spec| run_job_sequential_any(spec, &NaiveRefBackend, true))
        .collect();
    for r in &baseline {
        assert!(r.error.is_none(), "naive baseline job {}: {:?}", r.id, r.error);
    }
    let engine = EngineBuilder::new(8)
        .shared("native", Arc::new(NativeBackend::new(2)))
        .build();
    let report = engine.run(&mjobs, 4, true);
    for (seq, got) in baseline.iter().zip(&report.results) {
        assert!(got.error.is_none(), "mixed job {}", got.id);
        assert_eq!(
            seq.factors, got.factors,
            "packed engine differs from naive drivers: mixed job {} ({})",
            seq.id,
            seq.precision.name()
        );
        assert_eq!(seq.ipiv, got.ipiv, "mixed job {}", seq.id);
        assert_eq!(seq.fingerprint, got.fingerprint, "mixed job {}", seq.id);
        assert_eq!(
            seq.backward_error.map(f64::to_bits),
            got.backward_error.map(f64::to_bits),
            "mixed job {}",
            seq.id
        );
    }
}

/// Per-job `accum` determinism: one manifest mixing `accum=rounded` and
/// `accum=quire` jobs (factorize and refine, LU and Cholesky) must be
/// bit-identical to the sequential drivers at any worker count. Quire
/// jobs route through a different execution path — fused-dot panels and
/// `gemm_update_quire` trailing updates — so this pins that the batched
/// scheduler preserves *that* path's numerics too.
#[test]
fn mixed_accum_manifest_bit_identical_across_worker_counts() {
    let jobs = mixed_accum_manifest(10, 40);
    assert!(jobs.iter().any(|j| j.accum == Accum::Rounded));
    assert!(jobs.iter().any(|j| j.accum == Accum::Quire));
    assert!(
        jobs.iter().any(|j| j.accum == Accum::Quire && j.mode == Mode::Refine),
        "manifest must carry a quire refine job"
    );

    let backend = Arc::new(NativeBackend::new(2));
    let baseline: Vec<JobResult> = jobs
        .iter()
        .map(|spec| run_job_sequential_any(spec, &*backend, true))
        .collect();
    for r in &baseline {
        assert!(r.error.is_none(), "baseline job {}: {:?}", r.id, r.error);
    }

    for workers in [1usize, 4, 8] {
        let engine = EngineBuilder::new(8).shared("native", Arc::clone(&backend)).build();
        let report = engine.run(&jobs, workers, true);
        assert_eq!(report.results.len(), jobs.len());
        for (seq, got) in baseline.iter().zip(&report.results) {
            assert_eq!(seq.id, got.id);
            assert!(got.error.is_none(), "x{workers} job {}", got.id);
            // The accumulation mode rides through the engine untouched.
            assert_eq!(got.accum, jobs[got.id].accum, "x{workers} job {}", got.id);
            assert_eq!(
                seq.factors, got.factors,
                "factors differ: x{workers} job {} ({})",
                seq.id,
                seq.accum.name()
            );
            assert_eq!(seq.ipiv, got.ipiv, "pivots differ: x{workers} job {}", seq.id);
            assert_eq!(seq.fingerprint, got.fingerprint, "x{workers} job {}", seq.id);
            assert_eq!(
                seq.backward_error.map(f64::to_bits),
                got.backward_error.map(f64::to_bits),
                "x{workers} job {}",
                seq.id
            );
            assert_eq!(seq.refine_iters, got.refine_iters, "x{workers} job {}", seq.id);
        }
    }
}

/// The quire GEMM update must equal a one-rounding-per-output-element
/// reference built directly on the 512-bit [`Quire`], on wide-dynamic-
/// range Posit(32,2) inputs — and a planted absorption element pins
/// that deferred rounding genuinely diverges from round-per-mac.
#[test]
fn quire_gemm_matches_one_rounding_per_element_reference() {
    let (m, k, n) = (7, 24, 5);
    let (lda, ldb, ldc) = (m + 2, k, m + 1);
    let mut rng = Pcg64::seed(0x9D07);
    // Magnitudes spanning ~2^-40 .. 2^40: far outside the golden zone, so
    // per-mac rounding loses small addends that the quire keeps.
    let mut wide = |rng: &mut Pcg64| {
        let v = rng.loguniform(1e-12, 1e12);
        Posit32::from_f64(if rng.next_u64() & 1 == 0 { v } else { -v })
    };
    let mut a: Vec<Posit32> = (0..lda * k).map(|_| wide(&mut rng)).collect();
    let mut b: Vec<Posit32> = (0..ldb * n).map(|_| wide(&mut rng)).collect();
    let mut c0: Vec<Posit32> = (0..ldc * n).map(|_| wide(&mut rng)).collect();

    // Plant element (0,0) as a stepwise-absorption case: the first term
    // contributes exactly 1, each later term adds 2^-29 — below the
    // half-ulp 2^-28 at 1.0, so per-mac rounding absorbs every one of
    // them, while the quire's exact sum 1 + 23*2^-29 = 1 + 5.75*2^-27
    // rounds once to 1 + 6*2^-27.
    let tiny = Posit32::from_f64((2.0f64).powi(-29));
    c0[0] = Posit32::ZERO;
    a[0] = Posit32::ONE;
    b[0] = Posit32::ONE.negate();
    for l in 1..k {
        a[l * lda] = tiny;
        b[l] = Posit32::ONE.negate();
    }

    // Kernel under test.
    let mut c_quire = c0.clone();
    gemm_update_quire(m, k, n, &a, lda, &b, ldb, &mut c_quire, ldc);

    // Round-per-mac comparison point: the production rounded backend
    // (bit-identical to the ascending-k naive per-mac chain by the
    // repo-wide rounding contract).
    let mut c_rounded = c0.clone();
    NativeBackend::new(1)
        .gemm_update(m, k, n, &a, lda, &b, ldb, &mut c_rounded, ldc)
        .unwrap();

    for j in 0..n {
        for i in 0..m {
            // Independent one-rounding reference: load c, fuse the k
            // products in the quire, round once.
            let mut q = Quire::new();
            q.add_posit(c0[i + j * ldc].0);
            for l in 0..k {
                q.sub_product(a[i + l * lda].0, b[l + j * ldb].0);
            }
            assert_eq!(c_quire[i + j * ldc].0, q.to_posit_bits(), "element ({i},{j})");
        }
    }

    // The planted element: quire keeps the 23 tiny addends, the rounded
    // chain absorbs them all.
    let expect_quire = Posit32::from_f64(1.0 + 6.0 * (2.0f64).powi(-27));
    assert_eq!(c_quire[0], expect_quire, "planted element, quire path");
    assert_eq!(c_rounded[0], Posit32::ONE, "planted element, rounded path");
    assert_ne!(c_quire, c_rounded);
}

#[test]
fn repeated_runs_on_one_engine_are_bit_stable() {
    // A long-lived engine (the `serve` path) must reproduce itself round
    // after round: no hidden state drift in queues or backends.
    let jobs = mixed_manifest(6, 40);
    let engine = Engine::new(
        vec![(
            "native".to_string(),
            Arc::new(NativeBackend::new(2)) as Arc<dyn GemmBackend>,
        )],
        4,
    );
    let first = engine.run(&jobs, 4, false);
    for _ in 0..2 {
        let again = engine.run(&jobs, 3, false);
        for (a, b) in first.results.iter().zip(&again.results) {
            assert_eq!(a.fingerprint, b.fingerprint, "job {}", a.id);
        }
    }
}

#[test]
fn batching_actually_happens_with_many_workers() {
    // Not a numerics check: with 8 workers hammering one queue, at least
    // one contiguous submission should carry more than one tile (the
    // entire point of the dispatch queue). Retry a few times to keep the
    // test robust on slow single-core machines, where workers may never
    // overlap.
    let jobs = mixed_manifest(16, 40);
    for attempt in 0..5 {
        let engine = Engine::new(
            vec![(
                "native".to_string(),
                Arc::new(NativeBackend::new(1)) as Arc<dyn GemmBackend>,
            )],
            16,
        );
        let report = engine.run(&jobs, 8, false);
        assert_eq!(report.ok_count(), jobs.len());
        let q = &report.queues[0];
        assert!(q.tiles > 0 && q.batches > 0);
        if q.max_batch > 1 {
            return;
        }
        eprintln!("attempt {attempt}: no batch folded (max_batch=1), retrying");
    }
    // Machines with a single hardware thread may legitimately never fold;
    // don't fail the suite over scheduler behaviour.
    eprintln!("warning: dispatch queue never folded a batch on this machine");
}
