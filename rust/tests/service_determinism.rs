//! Service-layer determinism: the headline contract of `crate::service`.
//!
//! The same job manifest is run with 1, 4 and 8 workers on a shared
//! `NativeBackend` and a shared `TimedBackend`-modelled accelerator, and
//! every factor matrix, pivot vector and fingerprint must be bit-identical
//! to the sequential `*_offload` drivers on the same specs. Scheduling —
//! worker count, batch folding, pool interleaving — must never leak into
//! the numerics.
//!
//! The mixed-format tests extend the contract across the format-generic
//! API: one manifest carrying posit32 + f32 + f64 jobs (including
//! `mode=refine` mixed-precision jobs) must be bit-identical to the
//! sequential drivers *per format* at any worker count.

use posit_accel::blas::{gemm_naive, Scalar, Trans};
use posit_accel::coordinator::{GemmBackend, NativeBackend, TimedBackend};
use posit_accel::service::{
    mixed_format_manifest, mixed_manifest, run_job_sequential, run_job_sequential_any, Engine,
    EngineBuilder, JobResult, Mode, Precision,
};
use std::sync::Arc;

/// A backend that applies every update with the *reference* `gemm_naive`
/// kernel — the pre-packing GEMM semantics in their simplest form. The
/// engine's `NativeBackend` (now routed through `gemm_packed`) must
/// reproduce it bit-for-bit: rewiring the backends through the packed
/// microkernel must not change a single job output.
struct NaiveRefBackend;

impl<T: Scalar> GemmBackend<T> for NaiveRefBackend {
    fn name(&self) -> &str {
        "naive-ref"
    }
    fn gemm_update(
        &self,
        m: usize,
        k: usize,
        n: usize,
        a: &[T],
        lda: usize,
        b: &[T],
        ldb: usize,
        c: &mut [T],
        ldc: usize,
    ) -> anyhow::Result<()> {
        let minus1 = T::one().neg();
        gemm_naive(
            Trans::No,
            Trans::No,
            m,
            n,
            k,
            minus1,
            a,
            lda,
            b,
            ldb,
            T::one(),
            c,
            ldc,
        );
        Ok(())
    }
}

fn shared_backends() -> Vec<(&'static str, Arc<dyn GemmBackend>)> {
    vec![
        (
            "native",
            Arc::new(NativeBackend::new(2)) as Arc<dyn GemmBackend>,
        ),
        (
            "timed-fpga",
            Arc::new(TimedBackend::new(
                "timed-fpga",
                NativeBackend::new(2),
                // Toy cost model; the value is irrelevant to the contract.
                |m, k, n| (2 * m * k * n) as f64 / 200e9,
            )) as Arc<dyn GemmBackend>,
        ),
    ]
}

#[test]
fn factors_bit_identical_across_worker_counts_and_backends() {
    let jobs = mixed_manifest(10, 48);
    for (name, backend) in shared_backends() {
        // Ground truth: the plain sequential drivers, job by job.
        let baseline: Vec<JobResult> = jobs
            .iter()
            .map(|spec| run_job_sequential(spec, backend.as_ref(), true))
            .collect();
        for spec_result in &baseline {
            assert!(
                spec_result.error.is_none(),
                "baseline {name} job {}: {:?}",
                spec_result.id,
                spec_result.error
            );
        }
        for workers in [1usize, 4, 8] {
            let engine = Engine::new(vec![(name.to_string(), Arc::clone(&backend))], 8);
            let report = engine.run(&jobs, workers, true);
            assert_eq!(report.results.len(), jobs.len());
            for (seq, got) in baseline.iter().zip(&report.results) {
                assert_eq!(seq.id, got.id);
                assert!(got.error.is_none(), "{name} x{workers} job {}", got.id);
                assert_eq!(
                    seq.factors, got.factors,
                    "factors differ: {name} x{workers} job {}",
                    seq.id
                );
                assert_eq!(
                    seq.ipiv, got.ipiv,
                    "pivots differ: {name} x{workers} job {}",
                    seq.id
                );
                assert_eq!(seq.fingerprint, got.fingerprint);
                // The modelled accelerator seconds are part of the
                // deterministic contract too (pure function of the tile
                // shapes), unlike wall-clock phase timings.
                assert!(
                    (seq.stats.simulated_s - got.stats.simulated_s).abs() <= 1e-12,
                    "{name} x{workers} job {}: simulated {} vs {}",
                    seq.id,
                    seq.stats.simulated_s,
                    got.stats.simulated_s
                );
            }
        }
    }
}

/// Mixed-format determinism: one manifest carrying posit32 + f32 + f64
/// jobs (factorize and refine modes) through a shared format-transparent
/// backend must be bit-identical to the sequential drivers per format at
/// any worker count.
fn assert_mixed_manifest_deterministic<B>(name: &str, backend: Arc<B>)
where
    B: GemmBackend<posit_accel::posit::Posit32>
        + GemmBackend<f32>
        + GemmBackend<f64>
        + 'static,
{
    let mut jobs = mixed_format_manifest(12, 48);
    // The generator marks posit32 refine jobs (ids 3, 10); add an f32 and
    // an f64 refinement job so every format exercises the refine path.
    jobs[4].mode = Mode::Refine; // id 4: f32
    jobs[7].mode = Mode::Refine; // id 7: f64
    for p in Precision::ALL {
        assert!(jobs.iter().any(|j| j.precision == p), "manifest must mix formats");
    }
    assert!(jobs.iter().any(|j| j.mode == Mode::Refine && j.precision == Precision::F32));

    // Ground truth: the plain sequential drivers, job by job, format picked
    // from the spec.
    let baseline: Vec<JobResult> = jobs
        .iter()
        .map(|spec| run_job_sequential_any(spec, &*backend, true))
        .collect();
    for r in &baseline {
        assert!(r.error.is_none(), "baseline {name} job {}: {:?}", r.id, r.error);
    }

    for workers in [1usize, 4, 8] {
        let engine = EngineBuilder::new(8).shared(name, Arc::clone(&backend)).build();
        let report = engine.run(&jobs, workers, true);
        assert_eq!(report.results.len(), jobs.len());
        for (seq, got) in baseline.iter().zip(&report.results) {
            assert_eq!(seq.id, got.id);
            assert!(got.error.is_none(), "{name} x{workers} job {}", got.id);
            assert_eq!(got.precision, jobs[got.id].precision);
            assert_eq!(
                seq.factors, got.factors,
                "factors/solution differ: {name} x{workers} job {} ({})",
                seq.id,
                seq.precision.name()
            );
            assert_eq!(seq.ipiv, got.ipiv, "pivots differ: {name} x{workers} job {}", seq.id);
            assert_eq!(seq.fingerprint, got.fingerprint);
            // The accuracy numbers are pure functions of the factors — part
            // of the bit-determinism contract (compared as bits, not ≈).
            assert_eq!(
                seq.backward_error.map(f64::to_bits),
                got.backward_error.map(f64::to_bits),
                "{name} x{workers} job {}",
                seq.id
            );
            assert_eq!(seq.refine_iters, got.refine_iters);
            assert!(
                (seq.stats.simulated_s - got.stats.simulated_s).abs() <= 1e-12,
                "{name} x{workers} job {}: simulated {} vs {}",
                seq.id,
                seq.stats.simulated_s,
                got.stats.simulated_s
            );
        }
        // Tiles must have flowed through every format's own queue.
        for fmt in ["posit32", "binary32", "binary64"] {
            let q = report.queues.iter().find(|q| q.format == fmt).unwrap();
            assert!(q.tiles > 0, "{name} x{workers}: {fmt} queue saw no tiles");
        }
    }
}

#[test]
fn mixed_format_manifest_bit_identical_across_worker_counts() {
    assert_mixed_manifest_deterministic("native", Arc::new(NativeBackend::new(2)));
}

#[test]
fn mixed_format_manifest_bit_identical_on_modelled_accelerator() {
    assert_mixed_manifest_deterministic(
        "timed-fpga",
        Arc::new(TimedBackend::new(
            "timed-fpga",
            NativeBackend::new(2),
            |m, k, n| (2 * m * k * n) as f64 / 200e9,
        )),
    );
}

/// PR-4 guard: the whole engine — NativeBackend routed through the
/// decode-once packed GEMM, batched dispatch, any worker count — must be
/// bit-identical to the sequential drivers running on the naive reference
/// kernel, i.e. to the pre-packing semantics. Covers every format and
/// both factor and refine modes.
#[test]
fn packed_engine_matches_pre_packing_naive_semantics() {
    // Posit32 manifest.
    let jobs = mixed_manifest(8, 48);
    let baseline: Vec<JobResult> = jobs
        .iter()
        .map(|spec| {
            run_job_sequential::<posit_accel::posit::Posit32>(spec, &NaiveRefBackend, true)
        })
        .collect();
    for r in &baseline {
        assert!(r.error.is_none(), "naive baseline job {}: {:?}", r.id, r.error);
    }
    for workers in [1usize, 4] {
        let engine = Engine::new(
            vec![(
                "native".to_string(),
                Arc::new(NativeBackend::new(2)) as Arc<dyn GemmBackend>,
            )],
            8,
        );
        let report = engine.run(&jobs, workers, true);
        for (seq, got) in baseline.iter().zip(&report.results) {
            assert!(got.error.is_none(), "x{workers} job {}", got.id);
            assert_eq!(
                seq.factors, got.factors,
                "packed engine factors differ from naive drivers: x{workers} job {}",
                seq.id
            );
            assert_eq!(seq.ipiv, got.ipiv, "x{workers} job {}", seq.id);
            assert_eq!(seq.fingerprint, got.fingerprint, "x{workers} job {}", seq.id);
        }
    }

    // Mixed-format manifest (posit32 + f32 + f64, refine included).
    let mut mjobs = mixed_format_manifest(9, 40);
    mjobs[4].mode = Mode::Refine;
    let baseline: Vec<JobResult> = mjobs
        .iter()
        .map(|spec| run_job_sequential_any(spec, &NaiveRefBackend, true))
        .collect();
    for r in &baseline {
        assert!(r.error.is_none(), "naive baseline job {}: {:?}", r.id, r.error);
    }
    let engine = EngineBuilder::new(8)
        .shared("native", Arc::new(NativeBackend::new(2)))
        .build();
    let report = engine.run(&mjobs, 4, true);
    for (seq, got) in baseline.iter().zip(&report.results) {
        assert!(got.error.is_none(), "mixed job {}", got.id);
        assert_eq!(
            seq.factors, got.factors,
            "packed engine differs from naive drivers: mixed job {} ({})",
            seq.id,
            seq.precision.name()
        );
        assert_eq!(seq.ipiv, got.ipiv, "mixed job {}", seq.id);
        assert_eq!(seq.fingerprint, got.fingerprint, "mixed job {}", seq.id);
        assert_eq!(
            seq.backward_error.map(f64::to_bits),
            got.backward_error.map(f64::to_bits),
            "mixed job {}",
            seq.id
        );
    }
}

#[test]
fn repeated_runs_on_one_engine_are_bit_stable() {
    // A long-lived engine (the `serve` path) must reproduce itself round
    // after round: no hidden state drift in queues or backends.
    let jobs = mixed_manifest(6, 40);
    let engine = Engine::new(
        vec![(
            "native".to_string(),
            Arc::new(NativeBackend::new(2)) as Arc<dyn GemmBackend>,
        )],
        4,
    );
    let first = engine.run(&jobs, 4, false);
    for _ in 0..2 {
        let again = engine.run(&jobs, 3, false);
        for (a, b) in first.results.iter().zip(&again.results) {
            assert_eq!(a.fingerprint, b.fingerprint, "job {}", a.id);
        }
    }
}

#[test]
fn batching_actually_happens_with_many_workers() {
    // Not a numerics check: with 8 workers hammering one queue, at least
    // one contiguous submission should carry more than one tile (the
    // entire point of the dispatch queue). Retry a few times to keep the
    // test robust on slow single-core machines, where workers may never
    // overlap.
    let jobs = mixed_manifest(16, 40);
    for attempt in 0..5 {
        let engine = Engine::new(
            vec![(
                "native".to_string(),
                Arc::new(NativeBackend::new(1)) as Arc<dyn GemmBackend>,
            )],
            16,
        );
        let report = engine.run(&jobs, 8, false);
        assert_eq!(report.ok_count(), jobs.len());
        let q = &report.queues[0];
        assert!(q.tiles > 0 && q.batches > 0);
        if q.max_batch > 1 {
            return;
        }
        eprintln!("attempt {attempt}: no batch folded (max_batch=1), retrying");
    }
    // Machines with a single hardware thread may legitimately never fold;
    // don't fail the suite over scheduler behaviour.
    eprintln!("warning: dispatch queue never folded a batch on this machine");
}
