//! Bit-identity of the decode-once factorization pipeline.
//!
//! The contract (the README rounding-contract note for TRSM/panels):
//! `trsm`/`trsv`, the `getf2`/`potf2` panel sweeps and the offloaded
//! blocked drivers — all routed through the unpacked domain — produce
//! results **bit-identical** to the scalar references (`trsm_ref`,
//! `getf2_ref`, `potf2_ref`, `getrf_ref`, `potrf_ref`), including pivot
//! choices, error codes and the partial state failed sweeps leave behind.
//!
//! The Posit(8,2) sweeps are exhaustive in the operand values, in the
//! style of `gemm_packed.rs`: every ordered bit-pattern pair flows
//! through the pipeline's divide (1×1 solves), multiply-subtract (2-row
//! unit solves), pivot-compare/scale (2×2 `getf2`) and sqrt/divide (2×2
//! `potf2`) paths. Wide-dynamic-range Posit32 cases (long regimes,
//! cancellation, zeros, NaR) cover the 32-bit plane arithmetic's
//! saturation and special-value selects.

use posit_accel::blas::{
    trsm_ref, trsm_unpacked, trsv, Diag, Matrix, Scalar, Side, Trans, Uplo,
};
use posit_accel::coordinator::drivers::{getrf_offload, potrf_offload};
use posit_accel::coordinator::{GemmBackend, NativeBackend, TimedBackend};
use posit_accel::lapack::{
    getf2, getf2_ref, getrf_ref, potf2, potf2_ref, potrf_ref,
};
use posit_accel::posit::formats::P8;
use posit_accel::posit::Posit32;
use posit_accel::rng::Pcg64;

fn bits_of<T: Scalar>(v: &[T]) -> Vec<u64> {
    v.iter().map(|x| x.bits()).collect()
}

/// Every ordered Posit(8,2) pair through the TRSM divide path: the 1×1
/// NonUnit solve is exactly `x = b / a` with one rounding.
#[test]
fn p8_trsm_divide_pairs_exhaustive() {
    for a in 0u32..256 {
        // One call per divisor, all 256 numerators as right-hand sides.
        let diag = [P8(a)];
        let b0: Vec<P8> = (0u32..256).map(P8).collect();
        let mut b1 = b0.clone();
        let mut b2 = b0.clone();
        trsm_ref(
            Side::Left, Uplo::Lower, Trans::No, Diag::NonUnit, 1, 256,
            P8::from_f64(1.0), &diag, 1, &mut b1, 1,
        );
        trsm_unpacked(
            Side::Left, Uplo::Lower, Trans::No, Diag::NonUnit, 1, 256,
            P8::from_f64(1.0), &diag, 1, &mut b2, 1,
        );
        assert_eq!(bits_of(&b1), bits_of(&b2), "divisor {a:#x}");
    }
}

/// Every ordered Posit(8,2) pair through the TRSM multiply-subtract path:
/// in the 2-row unit-lower solve, `x2 = r - p*q` with `x1 = q` — so one
/// call per multiplier `p` covers all 256 `q` against rotating `r`.
#[test]
fn p8_trsm_mac_pairs_exhaustive() {
    let rset = [P8(0x00), P8(0x40), P8(0x80), P8(0xC7)];
    for p in 0u32..256 {
        // Unit diag: store garbage on the diagonal to prove it is ignored.
        let a = [P8(0x7F), P8(p), P8(0x55), P8(0x7F)]; // column-major 2x2
        for (ri, r) in rset.iter().enumerate() {
            let mut b0 = Vec::with_capacity(2 * 256);
            for q in 0..256u32 {
                b0.push(P8(q));
                b0.push(*r);
            }
            let mut b1 = b0.clone();
            let mut b2 = b0.clone();
            trsm_ref(
                Side::Left, Uplo::Lower, Trans::No, Diag::Unit, 2, 256,
                P8::from_f64(1.0), &a, 2, &mut b1, 2,
            );
            trsm_unpacked(
                Side::Left, Uplo::Lower, Trans::No, Diag::Unit, 2, 256,
                P8::from_f64(1.0), &a, 2, &mut b2, 2,
            );
            assert_eq!(bits_of(&b1), bits_of(&b2), "p {p:#x} r set {ri}");
        }
    }
}

/// Random Posit(8,2) systems (every pattern equally likely, so zero/NaR
/// and every regime keep appearing): all eight side/uplo/trans variants,
/// both diags, several alphas — unpacked vs scalar reference bitwise.
#[test]
fn p8_trsm_all_variants_random_bitwise() {
    let mut rng = Pcg64::seed(0xF8);
    let alphas = [
        P8::from_f64(1.0),
        P8::from_f64(-2.0),
        P8(0x00), // zero: scales everything to 0 (or NaR against NaR)
        P8(0x80), // NaR alpha poisons the whole solve
    ];
    for side in [Side::Left, Side::Right] {
        for uplo in [Uplo::Lower, Uplo::Upper] {
            for trans in [Trans::No, Trans::Yes] {
                for diag in [Diag::NonUnit, Diag::Unit] {
                    for &alpha in &alphas {
                        let (m, n) = (5usize, 7usize);
                        let asz = if side == Side::Left { m } else { n };
                        let a: Vec<P8> =
                            (0..asz * asz).map(|_| P8(rng.next_u32() & 255)).collect();
                        let b0: Vec<P8> =
                            (0..m * n).map(|_| P8(rng.next_u32() & 255)).collect();
                        let mut b1 = b0.clone();
                        let mut b2 = b0.clone();
                        trsm_ref(
                            side, uplo, trans, diag, m, n, alpha, &a, asz, &mut b1, m,
                        );
                        trsm_unpacked(
                            side, uplo, trans, diag, m, n, alpha, &a, asz, &mut b2, m,
                        );
                        assert_eq!(
                            bits_of(&b1),
                            bits_of(&b2),
                            "{side:?} {uplo:?} {trans:?} {diag:?} alpha {alpha:?}"
                        );
                    }
                }
            }
        }
    }
}

/// TRSV (strided) rides the decode-once TRSM: bitwise vs the scalar
/// reference gathered to a contiguous solve.
#[test]
fn p8_trsv_strided_matches_trsm_ref() {
    let mut rng = Pcg64::seed(0x75);
    for uplo in [Uplo::Lower, Uplo::Upper] {
        for trans in [Trans::No, Trans::Yes] {
            for diag in [Diag::NonUnit, Diag::Unit] {
                let n = 9usize;
                let a: Vec<P8> = (0..n * n).map(|_| P8(rng.next_u32() & 255)).collect();
                let x0: Vec<P8> = (0..n).map(|_| P8(rng.next_u32() & 255)).collect();
                // Reference: contiguous solve through the scalar TRSM.
                let mut want = x0.clone();
                trsm_ref(
                    Side::Left, uplo, trans, diag, n, 1, P8::from_f64(1.0), &a, n,
                    &mut want, n,
                );
                // trsv on a stride-3 embedding.
                let mut xs = vec![P8(0x33); 3 * n];
                for i in 0..n {
                    xs[3 * i] = x0[i];
                }
                trsv(uplo, trans, diag, n, &a, n, &mut xs, 3);
                for i in 0..n {
                    assert_eq!(
                        xs[3 * i].bits(),
                        want[i].bits(),
                        "{uplo:?} {trans:?} {diag:?} x[{i}]"
                    );
                }
                // Untouched stride padding.
                for (i, v) in xs.iter().enumerate() {
                    if i % 3 != 0 {
                        assert_eq!(v.bits(), 0x33, "padding at {i}");
                    }
                }
            }
        }
    }
}

/// Every ordered Posit(8,2) pair through the `getf2` pivot-compare,
/// swap, divide and multiply-subtract paths: 2×2 panels `[[p, u], [q, v]]`
/// with (p, q) exhaustive and (u, v) rotating. Pivots, factors and info
/// must match the scalar reference exactly.
#[test]
fn p8_getf2_pivot_divide_pairs_exhaustive() {
    // Two trailing-column pairs keep the debug-mode runtime in budget
    // while still driving the update path against a real, a zero and a
    // NaR trailing value; the (p, q) pivot/divide pair is exhaustive.
    let uvset = [(P8(0x40), P8(0x52)), (P8(0x80), P8(0x00))];
    for p in 0u32..256 {
        for q in 0u32..256 {
            for &(u, v) in &uvset {
                let a0 = [P8(p), P8(q), u, v]; // column-major 2x2
                let mut a1 = a0;
                let mut a2 = a0;
                let mut p1 = [0usize; 2];
                let mut p2 = [0usize; 2];
                let r1 = getf2_ref(2, 2, &mut a1, 2, &mut p1);
                let r2 = getf2(2, 2, &mut a2, 2, &mut p2);
                assert_eq!(r1, r2, "info p={p:#x} q={q:#x}");
                assert_eq!(p1, p2, "pivots p={p:#x} q={q:#x}");
                assert_eq!(bits_of(&a1), bits_of(&a2), "factors p={p:#x} q={q:#x}");
            }
        }
    }
}

/// A structured Posit(8,2) panel where every bit pattern appears both as
/// a pivot-column candidate and as a trailing-row multiplier, through the
/// full multi-step elimination (6 pivot steps over 256 columns).
#[test]
fn p8_getf2_structured_panel_sweep() {
    let (m, n) = (6usize, 256usize);
    let a0: Vec<P8> = {
        let mut v = Vec::with_capacity(m * n);
        for j in 0..n {
            for i in 0..m {
                v.push(P8(((j + 41 * i) & 255) as u32));
            }
        }
        v
    };
    let mut a1 = a0.clone();
    let mut a2 = a0.clone();
    let mut p1 = vec![0usize; m.min(n)];
    let mut p2 = vec![0usize; m.min(n)];
    let r1 = getf2_ref(m, n, &mut a1, m, &mut p1);
    let r2 = getf2(m, n, &mut a2, m, &mut p2);
    assert_eq!(r1, r2);
    assert_eq!(p1, p2);
    assert_eq!(bits_of(&a1), bits_of(&a2));
}

/// Every ordered Posit(8,2) pair through `potf2`'s sqrt and divide paths:
/// 2×2 lower blocks `[[p, *], [q, r]]` with (p, q) exhaustive. Factors,
/// error codes and the partial state of failed sweeps must all match.
#[test]
fn p8_potf2_sqrt_divide_pairs_exhaustive() {
    let rset = [P8(0x48), P8(0x80)];
    for p in 0u32..256 {
        for q in 0u32..256 {
            for (ri, r) in rset.iter().enumerate() {
                // Upper-triangle entry is garbage: potf2 must not read it.
                let a0 = [P8(p), P8(q), P8(0x7F), *r]; // column-major 2x2
                let mut a1 = a0;
                let mut a2 = a0;
                let r1 = potf2_ref(2, &mut a1, 2);
                let r2 = potf2(2, &mut a2, 2);
                assert_eq!(r1, r2, "info p={p:#x} q={q:#x} r set {ri}");
                assert_eq!(
                    bits_of(&a1),
                    bits_of(&a2),
                    "state p={p:#x} q={q:#x} r set {ri}"
                );
            }
        }
    }
}

/// Wide-dynamic-range Posit32 values (long regimes, huge/tiny scales,
/// zeros, NaR, cancellation-prone mixes) through every TRSM variant and
/// both panel factorizations, unpacked vs scalar reference bitwise.
#[test]
fn posit32_wide_range_trsm_and_panels_vs_ref() {
    let mut rng = Pcg64::seed(0x32F);
    let val = |rng: &mut Pcg64| -> Posit32 {
        match rng.next_u32() % 16 {
            0 => Posit32::ZERO,
            1 => Posit32::NAR,
            2..=5 => Posit32::from_f64(rng.normal()),
            6..=9 => {
                let e = (rng.next_u32() % 200) as i32 - 100;
                Posit32::from_f64(rng.normal() * 2f64.powi(e))
            }
            _ => Posit32(rng.next_u32()),
        }
    };
    for side in [Side::Left, Side::Right] {
        for uplo in [Uplo::Lower, Uplo::Upper] {
            for trans in [Trans::No, Trans::Yes] {
                for diag in [Diag::NonUnit, Diag::Unit] {
                    let (m, n) = (9usize, 6usize);
                    let asz = if side == Side::Left { m } else { n };
                    let a: Vec<Posit32> = (0..asz * asz).map(|_| val(&mut rng)).collect();
                    let b0: Vec<Posit32> = (0..m * n).map(|_| val(&mut rng)).collect();
                    let mut b1 = b0.clone();
                    let mut b2 = b0.clone();
                    trsm_ref(
                        side, uplo, trans, diag, m, n, Posit32::ONE, &a, asz, &mut b1, m,
                    );
                    trsm_unpacked(
                        side, uplo, trans, diag, m, n, Posit32::ONE, &a, asz, &mut b2, m,
                    );
                    assert_eq!(
                        bits_of(&b1),
                        bits_of(&b2),
                        "{side:?} {uplo:?} {trans:?} {diag:?}"
                    );
                }
            }
        }
    }
    // getf2 with NaR/zero injections, repeated trials.
    for trial in 0..40u64 {
        let (m, n) = (11usize, 8usize);
        let mut a0: Vec<Posit32> = (0..m * n).map(|_| val(&mut rng)).collect();
        if trial % 3 == 0 {
            a0[(trial as usize * 5) % (m * n)] = Posit32::NAR;
        }
        let mut a1 = a0.clone();
        let mut a2 = a0.clone();
        let mut p1 = vec![0usize; n];
        let mut p2 = vec![0usize; n];
        let r1 = getf2_ref(m, n, &mut a1, m, &mut p1);
        let r2 = getf2(m, n, &mut a2, m, &mut p2);
        assert_eq!(r1, r2, "trial {trial}");
        assert_eq!(p1, p2, "trial {trial}");
        assert_eq!(bits_of(&a1), bits_of(&a2), "trial {trial}");
    }
    // potf2 on SPD casts, plus corrupted variants (negative diag, NaR).
    for trial in 0..20u64 {
        let n = 10usize;
        let x = Matrix::<f64>::random_normal(n, n, 1.0, &mut rng);
        let mut s = Matrix::<f64>::identity(n);
        for v in s.data.iter_mut() {
            *v *= n as f64;
        }
        posit_accel::blas::gemm(
            Trans::Yes, Trans::No, n, n, n, 1.0, &x.data, n, &x.data, n, 1.0,
            &mut s.data, n,
        );
        let mut ap: Matrix<Posit32> = s.cast();
        match trial % 3 {
            1 => ap[(n / 2, n / 2)] = Posit32::from_f64(-1.0),
            2 => ap[(n - 2, n - 3)] = Posit32::NAR,
            _ => {}
        }
        let mut a1 = ap.clone();
        let mut a2 = ap.clone();
        let r1 = potf2_ref(n, &mut a1.data, n);
        let r2 = potf2(n, &mut a2.data, n);
        assert_eq!(r1, r2, "trial {trial}");
        assert_eq!(bits_of(&a1.data), bits_of(&a2.data), "trial {trial}");
    }
}

/// End-to-end: the offloaded drivers (decode-once panels + TRSM + pack
/// plans through the backend) must be bit-identical to the pre-pipeline
/// scalar-path blocked factorizations — through the plain native backend
/// AND a timed wrapper (which forwards the plan), at posit32 and f32,
/// with block sizes that do and do not divide n.
#[test]
fn offload_pipeline_bit_matches_scalar_path_factorizations() {
    let timed = TimedBackend::new("model", NativeBackend::new(2), |m, k, n| {
        (2 * m * k * n) as f64 / 1e9
    });
    let native = NativeBackend::new(2);
    for (n, nb) in [(64usize, 16usize), (90, 24)] {
        let mut rng = Pcg64::seed(9000 + n as u64);
        // --- LU, posit32.
        let a0 = Matrix::<Posit32>::random_normal(n, n, 1.0, &mut rng);
        let mut want = a0.clone();
        let mut want_piv = vec![0usize; n];
        getrf_ref(n, n, &mut want.data, n, &mut want_piv, nb, 2).unwrap();
        for be in [&native as &dyn GemmBackend<Posit32>, &timed] {
            let mut got = a0.clone();
            let mut piv = vec![0usize; n];
            let stats = getrf_offload(n, n, &mut got.data, n, &mut piv, nb, be).unwrap();
            assert_eq!(want_piv, piv, "{} n={n}", be.name());
            assert_eq!(want.data, got.data, "{} n={n}", be.name());
            assert!(stats.update_flops > 0.0);
        }
        // --- LU, f32 (the decode-once machinery is passthrough there, but
        // the pipeline rewiring must still change nothing).
        let af: Matrix<f32> = a0.cast();
        let mut wantf = af.clone();
        let mut wantf_piv = vec![0usize; n];
        getrf_ref(n, n, &mut wantf.data, n, &mut wantf_piv, nb, 2).unwrap();
        let mut gotf = af.clone();
        let mut pivf = vec![0usize; n];
        getrf_offload(n, n, &mut gotf.data, n, &mut pivf, nb, &native).unwrap();
        assert_eq!(wantf_piv, pivf, "f32 n={n}");
        assert_eq!(bits_of(&wantf.data), bits_of(&gotf.data), "f32 n={n}");
        // --- Cholesky, posit32 (lower triangle only: the offload update
        // overwrites the upper with GEMM results, like the pre-PR driver).
        let x = Matrix::<f64>::random_normal(n, n, 1.0, &mut rng);
        let mut s = Matrix::<f64>::identity(n);
        for v in s.data.iter_mut() {
            *v *= 0.5 * n as f64;
        }
        posit_accel::blas::gemm(
            Trans::Yes, Trans::No, n, n, n, 1.0, &x.data, n, &x.data, n, 1.0,
            &mut s.data, n,
        );
        let sp: Matrix<Posit32> = s.cast();
        let mut wantc = sp.clone();
        potrf_ref(n, &mut wantc.data, n, nb).unwrap();
        for be in [&native as &dyn GemmBackend<Posit32>, &timed] {
            let mut gotc = sp.clone();
            potrf_offload(n, &mut gotc.data, n, nb, be).unwrap();
            for j in 0..n {
                for i in j..n {
                    assert_eq!(
                        wantc[(i, j)],
                        gotc[(i, j)],
                        "{} L({i},{j}) n={n}",
                        be.name()
                    );
                }
            }
        }
    }
}
