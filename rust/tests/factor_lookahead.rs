//! Bit-identity and abort-safety of the lookahead-pipelined drivers.
//!
//! The lookahead contract (README performance section, DESIGN §7):
//! `getrf_offload_lookahead` / `potrf_offload_lookahead` reorder *when*
//! trailing updates run — next-panel columns first on the host, the
//! remainder in flight on the backend — never *what* is computed. So at
//! every depth, for every backend and format and accum mode, factors,
//! pivots and error codes must be **bit-identical** to the sequential
//! blocked references (`getrf_ref` / `potrf_ref` for rounded accumulation,
//! the depth-0 quire offload drivers for quire accumulation).
//!
//! The failure tests pin the abort path with an update genuinely in
//! flight (a real-time `TimedBackend`, so the submitted tail has a live
//! deadline when the pipeline hits the bad pivot): the error must be the
//! same variant and index as the sequential driver's, and the call must
//! return — no hung worker, no poisoned state.

use posit_accel::blas::{gemm, Matrix, Scalar, Trans};
use posit_accel::coordinator::drivers::{
    getrf_offload, getrf_offload_lookahead, getrf_offload_quire, getrf_offload_quire_lookahead,
    potrf_offload, potrf_offload_lookahead, potrf_offload_quire, potrf_offload_quire_lookahead,
};
use posit_accel::coordinator::{GemmBackend, NativeBackend, TimedBackend};
use posit_accel::lapack::{getrf_ref, potrf_ref};
use posit_accel::posit::Posit32;
use posit_accel::rng::Pcg64;

fn bits_of<T: Scalar>(v: &[T]) -> Vec<u64> {
    v.iter().map(|x| x.bits()).collect()
}

/// A general f64 test matrix, castable into every working format.
fn general_f64(n: usize, seed: u64) -> Matrix<f64> {
    let mut rng = Pcg64::seed(seed);
    Matrix::<f64>::random_normal(n, n, 1.0, &mut rng)
}

/// A well-conditioned SPD f64 test matrix (Gram + diagonal shift).
fn spd_f64(n: usize, seed: u64) -> Matrix<f64> {
    let mut rng = Pcg64::seed(seed);
    let x = Matrix::<f64>::random_normal(n, n, 1.0, &mut rng);
    let mut s = Matrix::<f64>::zeros(n, n);
    gemm(Trans::Yes, Trans::No, n, n, n, 1.0, &x.data, n, &x.data, n, 0.0, &mut s.data, n);
    for i in 0..n {
        s[(i, i)] += 0.5 * n as f64;
    }
    s
}

/// LU at depths 0/1/2 vs the blocked scalar reference, one format.
fn check_lu_depths<T: Scalar>(a64: &Matrix<f64>, n: usize, nb: usize) {
    let a0: Matrix<T> = a64.cast();
    let mut want = a0.clone();
    let mut want_piv = vec![0usize; n];
    getrf_ref(n, n, &mut want.data, n, &mut want_piv, nb, 2).unwrap();
    let native = NativeBackend::new(2);
    let timed = TimedBackend::new("model", NativeBackend::new(2), |m, k, nn| {
        (2 * m * k * nn) as f64 / 1e9
    });
    for be in [&native as &dyn GemmBackend<T>, &timed] {
        for depth in [0usize, 1, 2] {
            let mut got = a0.clone();
            let mut piv = vec![0usize; n];
            let stats =
                getrf_offload_lookahead(n, n, &mut got.data, n, &mut piv, nb, depth, be).unwrap();
            assert_eq!(want_piv, piv, "{} depth={depth} pivots", be.name());
            assert_eq!(
                bits_of(&want.data),
                bits_of(&got.data),
                "{} depth={depth} factors",
                be.name()
            );
            assert!(stats.update_flops > 0.0, "{} depth={depth}", be.name());
            if depth == 0 {
                assert_eq!(stats.overlap_s, 0.0, "depth 0 never overlaps");
            }
        }
    }
}

/// Cholesky at depths 0/1/2 vs the blocked scalar reference, one format.
fn check_chol_depths<T: Scalar>(s64: &Matrix<f64>, n: usize, nb: usize) {
    let a0: Matrix<T> = s64.cast();
    let mut want = a0.clone();
    potrf_ref(n, &mut want.data, n, nb).unwrap();
    let native = NativeBackend::new(2);
    let timed = TimedBackend::new("model", NativeBackend::new(2), |m, k, nn| {
        (2 * m * k * nn) as f64 / 1e9
    });
    for be in [&native as &dyn GemmBackend<T>, &timed] {
        for depth in [0usize, 1, 2] {
            let mut got = a0.clone();
            potrf_offload_lookahead(n, &mut got.data, n, nb, depth, be).unwrap();
            for j in 0..n {
                for i in j..n {
                    assert_eq!(
                        want[(i, j)].bits(),
                        got[(i, j)].bits(),
                        "{} depth={depth} L({i},{j})",
                        be.name()
                    );
                }
            }
        }
    }
}

/// LU lookahead is bit-identical to `getrf_ref` at every depth, for every
/// backend, at posit32, f32 and f64, with nb dividing n and not.
#[test]
fn lu_lookahead_depths_bit_match_reference_all_formats() {
    for (n, nb, seed) in [(64usize, 16usize, 700u64), (90, 24, 701)] {
        let a64 = general_f64(n, seed);
        check_lu_depths::<Posit32>(&a64, n, nb);
        check_lu_depths::<f32>(&a64, n, nb);
        check_lu_depths::<f64>(&a64, n, nb);
    }
}

/// Cholesky lookahead is bit-identical to `potrf_ref` at every depth, for
/// every backend, at posit32, f32 and f64.
#[test]
fn cholesky_lookahead_depths_bit_match_reference_all_formats() {
    for (n, nb, seed) in [(64usize, 16usize, 710u64), (90, 24, 711)] {
        let s64 = spd_f64(n, seed);
        check_chol_depths::<Posit32>(&s64, n, nb);
        check_chol_depths::<f32>(&s64, n, nb);
        check_chol_depths::<f64>(&s64, n, nb);
    }
}

/// Quire-accumulation LU: depths 1/2 are bit-identical to the sequential
/// quire driver (which depth 0 delegates to), pivots included.
#[test]
fn lu_quire_lookahead_depths_match_sequential() {
    let (n, nb) = (72usize, 20usize);
    let a64 = general_f64(n, 720);
    fn check<T: Scalar>(a64: &Matrix<f64>, n: usize, nb: usize) {
        let a0: Matrix<T> = a64.cast();
        let mut want = a0.clone();
        let mut want_piv = vec![0usize; n];
        getrf_offload_quire(n, n, &mut want.data, n, &mut want_piv, nb, &NativeBackend::new(2))
            .unwrap();
        let native = NativeBackend::new(2);
        let timed = TimedBackend::new("model", NativeBackend::new(2), |m, k, nn| {
            (2 * m * k * nn) as f64 / 1e9
        });
        for be in [&native as &dyn GemmBackend<T>, &timed] {
            for depth in [0usize, 1, 2] {
                let mut got = a0.clone();
                let mut piv = vec![0usize; n];
                getrf_offload_quire_lookahead(
                    n, n, &mut got.data, n, &mut piv, nb, depth, be,
                )
                .unwrap();
                assert_eq!(want_piv, piv, "{} depth={depth} pivots", be.name());
                assert_eq!(
                    bits_of(&want.data),
                    bits_of(&got.data),
                    "{} depth={depth} factors",
                    be.name()
                );
            }
        }
    }
    check::<Posit32>(&a64, n, nb);
    check::<f32>(&a64, n, nb);
    check::<f64>(&a64, n, nb);
}

/// Quire-accumulation Cholesky: depths 1/2 bit-identical to the
/// sequential quire driver's lower triangle.
#[test]
fn cholesky_quire_lookahead_depths_match_sequential() {
    let (n, nb) = (72usize, 20usize);
    let s64 = spd_f64(n, 721);
    fn check<T: Scalar>(s64: &Matrix<f64>, n: usize, nb: usize) {
        let a0: Matrix<T> = s64.cast();
        let mut want = a0.clone();
        potrf_offload_quire(n, &mut want.data, n, nb, &NativeBackend::new(2)).unwrap();
        let native = NativeBackend::new(2);
        let timed = TimedBackend::new("model", NativeBackend::new(2), |m, k, nn| {
            (2 * m * k * nn) as f64 / 1e9
        });
        for be in [&native as &dyn GemmBackend<T>, &timed] {
            for depth in [0usize, 1, 2] {
                let mut got = a0.clone();
                potrf_offload_quire_lookahead(n, &mut got.data, n, nb, depth, be).unwrap();
                for j in 0..n {
                    for i in j..n {
                        assert_eq!(
                            want[(i, j)].bits(),
                            got[(i, j)].bits(),
                            "{} depth={depth} L({i},{j})",
                            be.name()
                        );
                    }
                }
            }
        }
    }
    check::<Posit32>(&s64, n, nb);
    check::<f32>(&s64, n, nb);
}

/// A singular panel hit mid-pipeline (updates in flight on a real-time
/// timed backend) must defer exactly like the sequential driver: the
/// factorization completes, the error is the same `SingularU` index, and
/// the call returns promptly — no hung backend worker.
#[test]
fn lu_lookahead_singular_mid_pipeline_aborts_like_sequential() {
    let n = 32usize;
    let nb = 8usize;
    // Rank-1 matrix: the second elimination column is exactly zero, so
    // the singularity lands in the first panel with updates still queued
    // behind it at depth >= 1.
    let mut a = Matrix::<Posit32>::zeros(n, n);
    for j in 0..n {
        for i in 0..n {
            a[(i, j)] = Posit32::from_f64(((i + 1) * (j + 1)) as f64);
        }
    }
    let mut want = a.clone();
    let mut want_piv = vec![0usize; n];
    let want_err = getrf_offload(n, n, &mut want.data, n, &mut want_piv, nb, &NativeBackend::new(1))
        .unwrap_err();
    let timed = TimedBackend::new("rt", NativeBackend::new(2), |_, _, _| 2e-3).with_real_time();
    for depth in [1usize, 2] {
        let mut got = a.clone();
        let mut piv = vec![0usize; n];
        let err =
            getrf_offload_lookahead(n, n, &mut got.data, n, &mut piv, nb, depth, &timed)
                .unwrap_err();
        assert_eq!(want_err, err, "depth={depth}");
        // Deferred singularity still finishes the factorization: the
        // partial state matches the sequential driver's bit-for-bit.
        assert_eq!(want_piv, piv, "depth={depth} pivots");
        assert_eq!(bits_of(&want.data), bits_of(&got.data), "depth={depth} state");
    }
}

/// A non-SPD pivot in a *later* block (so the pipeline has a trailing
/// update in flight when the panel fails) must abort with the same
/// `NotPositiveDefinite` index as the sequential driver, and return.
#[test]
fn cholesky_lookahead_non_spd_mid_pipeline_aborts_like_sequential() {
    let n = 64usize;
    let nb = 16usize;
    let mut s = spd_f64(n, 730);
    // Poison a diagonal entry inside the third block: blocks 0..2 factor
    // cleanly, so at depth >= 1 the failing potf2 runs while the previous
    // step's tail update is in flight.
    s[(2 * nb + 3, 2 * nb + 3)] = -1.0;
    let sp: Matrix<Posit32> = s.cast();
    let mut want = sp.clone();
    let want_err =
        potrf_offload(n, &mut want.data, n, nb, &NativeBackend::new(1)).unwrap_err();
    let timed = TimedBackend::new("rt", NativeBackend::new(2), |_, _, _| 2e-3).with_real_time();
    for depth in [1usize, 2] {
        let mut got = sp.clone();
        let err = potrf_offload_lookahead(n, &mut got.data, n, nb, depth, &timed).unwrap_err();
        assert_eq!(want_err, err, "depth={depth}");
    }
}

/// On a real-time timed backend the pipeline actually overlaps: depth 1
/// reports overlap_s > 0 (host panel work ran while an update was in
/// flight) and a sane overlap fraction; depth 0 reports none.
#[test]
fn lookahead_overlap_is_observed_on_real_time_backend() {
    let n = 96usize;
    let nb = 24usize;
    let a64 = general_f64(n, 740);
    let a0: Matrix<Posit32> = a64.cast();
    let timed =
        TimedBackend::new("rt", NativeBackend::new(2), |_, _, _| 4e-3).with_real_time();

    let mut seq = a0.clone();
    let mut seq_piv = vec![0usize; n];
    let s0 = getrf_offload_lookahead(n, n, &mut seq.data, n, &mut seq_piv, nb, 0, &timed).unwrap();
    assert_eq!(s0.overlap_s, 0.0, "sequential schedule has nothing in flight");

    let mut got = a0.clone();
    let mut piv = vec![0usize; n];
    let s1 = getrf_offload_lookahead(n, n, &mut got.data, n, &mut piv, nb, 1, &timed).unwrap();
    assert_eq!(seq_piv, piv);
    assert_eq!(bits_of(&seq.data), bits_of(&got.data));
    assert!(s1.overlap_s > 0.0, "depth 1 on a real-time backend must overlap");
    let f = s1.overlap_fraction();
    assert!(f > 0.0 && f <= 1.0, "overlap fraction {f} out of range");
    assert!(s1.wait_s >= 0.0);
}
