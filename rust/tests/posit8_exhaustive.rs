//! Exhaustive Posit(8,2) closure: every regime/rounding edge case, not a
//! randomized sample.
//!
//! Two exhaustive cross-checks of the SoftPosit-style counting engine
//! (`posit::generic`, the implementation the paper ports to GPUs):
//!
//! 1. **vs. a branchless oracle** — all 256×256 add/mul/div pairs and all
//!    256 sqrt inputs against straight-line f64 arithmetic + one posit
//!    rounding. Valid because every Posit(8,2) value is a small dyadic
//!    rational: each f64 op result is either exact (add/mul: ≤ 25-bit
//!    scaled integers) or, for div/sqrt, at least ~2^-25 away (relative)
//!    from any Posit(8,2) rounding boundary while f64's own error is
//!    2^-53 — so the double rounding can never flip a posit decision.
//!    (Verified independently against the exact-rational Python oracle
//!    over the full 256×256 space when this test was authored.)
//! 2. **instrumented vs. plain** — the same ops traced with a `Profile`
//!    must return the same bits as with `NoTrace`: instruction counting
//!    must be observationally pure.

use posit_accel::posit::generic::{NoTrace, PositSpec, Profile};

const SPEC: PositSpec = PositSpec::P8;

#[test]
fn exhaustive_p8_ops_match_branchless_f64_oracle() {
    let nar = SPEC.nar();
    let mut t = NoTrace;
    let vals: Vec<f64> = (0..256u32).map(|bits| SPEC.to_f64(bits)).collect();
    for a in 0..256u32 {
        let fa = vals[a as usize];
        let s = SPEC.sqrt(a, &mut t);
        if a == 0 {
            assert_eq!(s, 0, "sqrt(0)");
        } else if a == nar || a >> 7 == 1 {
            assert_eq!(s, nar, "sqrt({a:#04x}) of NaR/negative");
        } else {
            assert_eq!(s, SPEC.from_f64(fa.sqrt()), "sqrt({a:#04x})");
        }
        for b in 0..256u32 {
            let fb = vals[b as usize];
            let add = SPEC.add(a, b, &mut t);
            let mul = SPEC.mul(a, b, &mut t);
            let div = SPEC.div(a, b, &mut t);
            if a == nar || b == nar {
                assert_eq!(add, nar, "add NaR {a:#04x} {b:#04x}");
                assert_eq!(mul, nar, "mul NaR {a:#04x} {b:#04x}");
                assert_eq!(div, nar, "div NaR {a:#04x} {b:#04x}");
                continue;
            }
            assert_eq!(add, SPEC.from_f64(fa + fb), "add {a:#04x} {b:#04x}");
            assert_eq!(mul, SPEC.from_f64(fa * fb), "mul {a:#04x} {b:#04x}");
            if b == 0 {
                assert_eq!(div, nar, "div by zero {a:#04x}");
            } else {
                assert_eq!(div, SPEC.from_f64(fa / fb), "div {a:#04x} {b:#04x}");
            }
        }
    }
}

#[test]
fn exhaustive_p8_instrumentation_is_observationally_pure() {
    let mut plain = NoTrace;
    for a in 0..256u32 {
        let mut p = Profile::default();
        assert_eq!(SPEC.sqrt(a, &mut p), SPEC.sqrt(a, &mut plain), "sqrt {a:#04x}");
        for b in 0..256u32 {
            let mut p = Profile::default();
            assert_eq!(
                SPEC.add(a, b, &mut p),
                SPEC.add(a, b, &mut plain),
                "add {a:#04x} {b:#04x}"
            );
            assert_eq!(
                SPEC.mul(a, b, &mut p),
                SPEC.mul(a, b, &mut plain),
                "mul {a:#04x} {b:#04x}"
            );
            assert_eq!(
                SPEC.div(a, b, &mut p),
                SPEC.div(a, b, &mut plain),
                "div {a:#04x} {b:#04x}"
            );
            // Every traced op executed at least one instruction and one
            // branch decision; sanity that tracing engaged at all.
            assert!(p.inst > 0 && p.cont > 0);
        }
    }
}

#[test]
fn exhaustive_p8_negation_and_commutativity() {
    // Cheap algebraic closure on the same exhaustive domain: add/mul are
    // commutative, negation is an involution and distributes over add's
    // result exactly (posit negation is exact).
    let nar = SPEC.nar();
    let mut t = NoTrace;
    for a in 0..256u32 {
        assert_eq!(SPEC.negate(SPEC.negate(a)), a);
        for b in 0..256u32 {
            assert_eq!(SPEC.add(a, b, &mut t), SPEC.add(b, a, &mut t));
            assert_eq!(SPEC.mul(a, b, &mut t), SPEC.mul(b, a, &mut t));
            if a != nar && b != nar {
                assert_eq!(
                    SPEC.negate(SPEC.add(a, b, &mut t)),
                    SPEC.add(SPEC.negate(a), SPEC.negate(b), &mut t),
                    "-(a+b) == (-a)+(-b) for {a:#04x} {b:#04x}"
                );
            }
        }
    }
}
