//! End-to-end integration: the full three-layer stack.
//!
//! Exercises Python-authored AOT artifacts (L1/L2) through the PJRT
//! runtime, the coordinator's offloaded factorizations (L3), and the
//! numerics contract that ties them together: every backend produces
//! bit-identical factors, and solving a real system achieves the paper's
//! accuracy behaviour.

use posit_accel::blas::{self, Matrix};
use posit_accel::coordinator::drivers::{getrf_offload, potrf_offload};
use posit_accel::coordinator::{GemmBackend, NativeBackend, PjrtBackend};
use posit_accel::experiments::matgen;
use posit_accel::lapack::{self, backward_error};
use posit_accel::posit::Posit32;
use posit_accel::rng::Pcg64;
use posit_accel::runtime::Runtime;

fn pjrt() -> Option<PjrtBackend> {
    let dir = Runtime::default_dir();
    if !dir.is_dir() {
        eprintln!("skipping PJRT parts: run `make artifacts`");
        return None;
    }
    Some(PjrtBackend::new(dir).expect("artifacts present but unloadable"))
}

#[test]
fn lu_bit_identical_across_all_backends() {
    let n = 200;
    let mut rng = Pcg64::seed(0xE2E);
    let a0 = Matrix::<Posit32>::random_normal(n, n, 1.0, &mut rng);

    let run = |be: &dyn GemmBackend| {
        let mut a = a0.clone();
        let mut ipiv = vec![0usize; n];
        getrf_offload(n, n, &mut a.data, n, &mut ipiv, 64, be).unwrap();
        (a, ipiv)
    };
    let (a_lapack, p_lapack) = {
        let mut a = a0.clone();
        let mut ipiv = vec![0usize; n];
        lapack::getrf(n, n, &mut a.data, n, &mut ipiv, 64, 4).unwrap();
        (a, ipiv)
    };
    let (a_native, p_native) = run(&NativeBackend::new(4));
    assert_eq!(p_lapack, p_native);
    assert_eq!(a_lapack.data, a_native.data, "coordinator == lapack");
    if let Some(be) = pjrt() {
        let (a_pjrt, p_pjrt) = run(&be);
        assert_eq!(p_native, p_pjrt);
        assert_eq!(
            a_native.data, a_pjrt.data,
            "AOT Pallas artifact == native rust, bit for bit"
        );
        assert!(be.tiles_dispatched() > 0);
    }
}

#[test]
fn cholesky_bit_identical_native_vs_pjrt() {
    let n = 160;
    let mut rng = Pcg64::seed(0xC4);
    let a64 = matgen::spd_f64(n, 1.0, &mut rng);
    let ap: Matrix<Posit32> = a64.cast();
    let mut l1 = ap.clone();
    potrf_offload(n, &mut l1.data, n, 64, &NativeBackend::new(2)).unwrap();
    if let Some(be) = pjrt() {
        let mut l2 = ap.clone();
        potrf_offload(n, &mut l2.data, n, 64, &be).unwrap();
        for j in 0..n {
            for i in j..n {
                assert_eq!(l1[(i, j)], l2[(i, j)], "L({i},{j})");
            }
        }
    }
}

#[test]
fn full_solve_via_pjrt_offload_hits_paper_accuracy() {
    // The paper's protocol end to end THROUGH THE ACCELERATOR: factorize
    // with the PJRT backend, solve, measure backward error in f64, and
    // compare with binary32 LAPACK on the same problem.
    let Some(be) = pjrt() else { return };
    let n = 192;
    let mut rng = Pcg64::seed(0x50E);
    let a64 = matgen::normal_f64(n, 1.0, &mut rng);
    let (_xsol, b64) = matgen::rhs_for(&a64);

    // posit through the offload stack.
    let (ap, mut bp) = matgen::cast_problem::<Posit32>(&a64, &b64);
    let mut lu = ap;
    let mut ipiv = vec![0usize; n];
    getrf_offload(n, n, &mut lu.data, n, &mut ipiv, 64, &be).unwrap();
    lapack::getrs(n, 1, &lu.data, n, &ipiv, &mut bp, n);
    let e_posit = backward_error(&a64, &b64, &bp);

    // binary32 reference.
    let (af, mut bf) = matgen::cast_problem::<f32>(&a64, &b64);
    let mut luf = af;
    let mut ipivf = vec![0usize; n];
    lapack::getrf(n, n, &mut luf.data, n, &mut ipivf, 64, 2).unwrap();
    lapack::getrs(n, 1, &luf.data, n, &ipivf, &mut bf, n);
    let e_f32 = backward_error(&a64, &b64, &bf);

    let digits = (e_f32 / e_posit).log10();
    assert!(
        digits > 0.3,
        "posit-through-PJRT should beat binary32 at σ=1: {digits:+.2} \
         (e_posit {e_posit:.2e}, e_f32 {e_f32:.2e})"
    );
}

#[test]
fn failure_injection_nar_and_singularity_propagate() {
    let n = 64;
    let mut rng = Pcg64::seed(3);
    // NaR hidden in the trailing matrix reaches the panel eventually and
    // surfaces as an error, not a hang or silent garbage.
    let mut a = Matrix::<Posit32>::random_normal(n, n, 1.0, &mut rng);
    a[(40, 50)] = Posit32::NAR;
    let mut ipiv = vec![0usize; n];
    let r = getrf_offload(n, n, &mut a.data, n, &mut ipiv, 16, &NativeBackend::new(1));
    // NaR-contaminated pivots compare as minimal, so factorization either
    // flags a bad value or completes with NaR in U; both are detectable.
    match r {
        Err(_) => {}
        Ok(_) => assert!(a.any_bad(), "NaR must not vanish"),
    }

    // Exactly singular matrix reports SingularU with the right column.
    let mut s = Matrix::<Posit32>::zeros(n, n);
    for j in 0..n {
        for i in 0..n {
            s[(i, j)] = Posit32::from_f64(((i + 2) * (j + 1)) as f64);
        }
    }
    let err = getrf_offload(n, n, &mut s.data, n, &mut ipiv, 16, &NativeBackend::new(1))
        .unwrap_err();
    assert!(matches!(err, lapack::LapackError::SingularU(_)));
}

#[test]
fn elementwise_artifacts_match_scalar_ops_broadly() {
    let Some(_) = pjrt() else { return };
    let rt = Runtime::new(Runtime::default_dir()).unwrap();
    let len = 65536;
    let mut rng = Pcg64::seed(9);
    // Heavy on specials.
    let a: Vec<u32> = (0..len)
        .map(|i| match i % 7 {
            0 => 0,
            1 => 0x8000_0000,
            2 => 0x7FFF_FFFF,
            _ => rng.next_u32(),
        })
        .collect();
    let b: Vec<u32> = (0..len).map(|_| rng.next_u32()).collect();
    let got = rt.elementwise("mul", &a, Some(&b)).unwrap();
    for i in 0..len {
        assert_eq!(got[i], posit_accel::posit::mul(a[i], b[i]), "lane {i}");
    }
}

#[test]
fn blas_gemm_transposes_consistent_with_pretransposed_nn() {
    // The runtime only ships NN kernels (like the paper's FPGA); verify
    // host pre-transposition gives the same results as the native T path.
    let (m, n, k) = (48, 32, 24);
    let mut rng = Pcg64::seed(12);
    let a = Matrix::<Posit32>::random_normal(k, m, 1.0, &mut rng); // A^T stored
    let b = Matrix::<Posit32>::random_normal(k, n, 1.0, &mut rng);
    let mut c1 = Matrix::<Posit32>::zeros(m, n);
    let mut c2 = Matrix::<Posit32>::zeros(m, n);
    blas::gemm(
        blas::Trans::Yes, blas::Trans::No, m, n, k, Posit32::ONE, &a.data, k,
        &b.data, k, Posit32::ZERO, &mut c1.data, m,
    );
    let at = a.transposed();
    blas::gemm(
        blas::Trans::No, blas::Trans::No, m, n, k, Posit32::ONE, &at.data, m,
        &b.data, k, Posit32::ZERO, &mut c2.data, m,
    );
    assert_eq!(c1.data, c2.data);
}
