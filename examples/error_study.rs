//! The paper's numerical-error study (Fig 7) as a standalone example:
//! sweep σ for both decompositions and print the posit-vs-binary32
//! advantage in digits, plus a golden-zone visualization.
//!
//! ```sh
//! cargo run --release --example error_study -- [N]
//! ```

use posit_accel::experiments::fig7::{error_cell, SIGMAS};
use posit_accel::posit::{eps_for_scale, Posit32};

fn main() {
    let n: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(192);

    println!("== golden zone of Posit(32,2) (paper §2) ==");
    println!("   |x|        eps_posit    vs binary32");
    for e in [-40, -20, -6, -3, 0, 3, 6, 20, 40] {
        let v = 10f64.powi(e);
        let scale = v.log2().round() as i32;
        let eps = eps_for_scale(scale.clamp(-120, 120));
        let rel = 6.0e-8 / eps;
        let bar = if rel >= 1.0 { "posit wins" } else { "binary32 wins" };
        println!("  1e{e:+03}      {eps:9.1e}    {rel:8.1}x  {bar}");
    }
    let _ = Posit32::ONE;

    println!("\n== Fig 7 protocol at N={n} (measured; 2 matrices per cell) ==");
    for (label, chol) in [("LU", false), ("Cholesky", true)] {
        println!("\n{label}: advantage of posit over binary32, in digits");
        print!("   ");
        for s in SIGMAS {
            print!("  σ={s:<7.0e}");
        }
        println!();
        print!("   ");
        for (i, s) in SIGMAS.iter().enumerate() {
            match error_cell(chol, n, *s, 2, 99 + i as u64) {
                Some(c) => print!("  {:+9.2}", c.digits),
                None => print!("  {:>9}", "fail"),
            }
        }
        println!();
    }
    println!(
        "\npositive = posit more accurate. Expected shape (paper Fig 7):\n\
         +0.5..1 digit at σ <= 1, ~0 at σ = 1e2, negative beyond;\n\
         Cholesky degrades faster (A = XᵀX squares the norm)."
    );
}
