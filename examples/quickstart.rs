//! Quickstart: the Posit(32,2) format and the GEMM API in two minutes.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use posit_accel::blas::{dot, dot_quire, gemm, Matrix, Trans};
use posit_accel::posit::{eps_for_scale, Posit32};
use posit_accel::rng::Pcg64;

fn main() {
    // --- scalars ----------------------------------------------------------
    let a = Posit32::from_f64(1.5);
    let b = Posit32::from_f64(2.25);
    println!("1.5 + 2.25   = {}", a + b);
    println!("1.5 * 2.25   = {}", a * b);
    println!("sqrt(2.25)   = {}", Posit32::from_f64(2.25).sqrt());
    println!("1.5 bits     = {:#010x}", a.to_bits());
    println!("maxpos       = {:e}", Posit32::MAXPOS.to_f64());
    println!("NaR          = {}", Posit32::NAR);
    println!("1/0          = {}", Posit32::ONE / Posit32::ZERO);

    // --- tapered precision: the "golden zone" (paper §2) -------------------
    println!("\ntapered precision (rounding step at scale s):");
    for v in [1.0f64, 1e-3, 1e3, 1e9, 1e-30] {
        let scale = v.log2().round() as i32;
        println!(
            "  |x| ~ {v:>6.0e}: eps_posit = {:.1e}   (binary32 eps = 6.0e-8)",
            eps_for_scale(scale)
        );
    }

    // --- vectors: sequential vs fused (quire) dot --------------------------
    let mut rng = Pcg64::seed(42);
    let n = 10_000;
    let xs: Vec<Posit32> = (0..n).map(|_| Posit32::from_f64(rng.normal())).collect();
    let ys: Vec<Posit32> = (0..n).map(|_| Posit32::from_f64(rng.normal())).collect();
    let truth: f64 = xs.iter().zip(&ys).map(|(x, y)| x.to_f64() * y.to_f64()).sum();
    let seq = dot(n, &xs, 1, &ys, 1);
    let fused = dot_quire(n, &xs, 1, &ys, 1);
    println!("\ndot product of {n} N(0,1) pairs:");
    println!("  exact (f64)     = {truth:.12}");
    println!("  sequential      = {:.12}", seq.to_f64());
    println!("  quire (1 round) = {:.12}", fused.to_f64());

    // --- GEMM: the paper's Eq. (2) -----------------------------------------
    let (m, k, nn) = (64, 64, 64);
    let a = Matrix::<Posit32>::random_normal(m, k, 1.0, &mut rng);
    let b = Matrix::<Posit32>::random_normal(k, nn, 1.0, &mut rng);
    let mut c = Matrix::<Posit32>::zeros(m, nn);
    gemm(
        Trans::No, Trans::No, m, nn, k, Posit32::ONE, &a.data, m, &b.data, k,
        Posit32::ZERO, &mut c.data, m,
    );
    println!("\nRgemm {m}x{k}x{nn}: C[0,0] = {}", c[(0, 0)]);
    println!("\nnext: examples/lu_solve.rs runs the full accelerator stack.");
}
