//! FPGA vs GPU as Posit(32,2) accelerators — the paper's §6 comparison on
//! one page: square GEMM, trailing updates, power caps, and decomposition
//! end-to-end, all from the calibrated hardware models, with a real
//! measured run of this host's stack alongside.
//!
//! ```sh
//! cargo run --release --example accelerator_compare
//! ```

use posit_accel::coordinator::drivers::{getrf_offload, lu_ops};
use posit_accel::coordinator::{NativeBackend, TimedBackend};
use posit_accel::posit::Posit32;
use posit_accel::rng::Pcg64;
use posit_accel::sim::gpu::GpuModel;
use posit_accel::sim::power::cap_factor;
use posit_accel::sim::specs::{RTX4090, V100};
use posit_accel::sim::systolic::SystolicConfig;
use posit_accel::{blas, util::Table};

fn main() {
    let gm = GpuModel::new();
    let fpga = SystolicConfig::agilex_posit32();

    // 1. Square GEMM: who wins where (paper §4.4).
    let mut t = Table::new(
        "square posit GEMM Gflops (models): FPGA wins only at large N",
        &["N", "Agilex", "V100", "RTX4090"],
    );
    for n in [1000usize, 2000, 4000, 8000] {
        t.row(&[
            n.to_string(),
            format!("{:.0}", fpga.gemm_gflops_square(n)),
            format!("{:.0}", gm.gemm_gflops_square(&V100, n, 1.0)),
            format!("{:.0}", gm.gemm_gflops_square(&RTX4090, n, 1.0)),
        ]);
    }
    print!("{}", t.render());

    // 2. Trailing update: the FPGA's weakness (Fig 6).
    let mut t = Table::new(
        "trailing update (4000xKx4000), % of own peak",
        &["K", "Agilex", "RTX4090"],
    );
    for k in [32usize, 64, 128, 512] {
        let f = fpga.gemm_gflops_update(4000, k) / fpga.f_peak_gflops();
        let g = gm.gemm_gflops(&RTX4090, 4000, k, 4000, 1.0)
            / gm.gemm_gflops_square(&RTX4090, 8000, 1.0);
        t.row(&[
            k.to_string(),
            format!("{:.0}%", f * 100.0),
            format!("{:.0}%", g * 100.0),
        ]);
    }
    print!("{}", t.render());

    // 3. Power caps (Fig 5 punchline).
    let mut t = Table::new(
        "GEMM at N=8000 under power caps (Gflops)",
        &["cap W", "V100", "RTX4090"],
    );
    for cap in [250.0, 150.0, 100.0] {
        t.row(&[
            format!("{cap:.0}"),
            format!("{:.0}", gm.gemm_gflops_square(&V100, 8000, 1.0) * cap_factor(&V100, cap)),
            format!("{:.0}", gm.gemm_gflops_square(&RTX4090, 8000, 1.0) * cap_factor(&RTX4090, cap)),
        ]);
    }
    print!("{}", t.render());

    // 4. A real decomposition on this host with simulated-accelerator
    //    clocks attached: TimedBackend computes real posit numerics while
    //    charging each update to the modelled FPGA / GPU.
    let n = 256;
    let mut rng = Pcg64::seed(5);
    let a0 = blas::Matrix::<Posit32>::random_normal(n, n, 1.0, &mut rng);
    let mut t = Table::new(
        &format!("offloaded LU at N={n}: real numerics, modelled accelerator clocks"),
        &["accelerator", "simulated accel s", "host wall s", "modelled Gflops"],
    );
    let fpga_cfg = fpga;
    for (label, model) in [
        (
            "Agilex 16x16",
            Box::new(move |m: usize, k: usize, nn: usize| fpga_cfg.gemm_seconds(m, k, nn))
                as Box<dyn Fn(usize, usize, usize) -> f64>,
        ),
        (
            "RTX4090",
            Box::new(move |m: usize, k: usize, nn: usize| {
                GpuModel::new().gemm_seconds(&RTX4090, m, k, nn, 1.0)
            }),
        ),
    ] {
        let be = TimedBackend::new(label, NativeBackend::new(blas::default_threads()), model);
        let mut a = a0.clone();
        let mut ipiv = vec![0usize; n];
        let stats = getrf_offload(n, n, &mut a.data, n, &mut ipiv, 64, &be).unwrap();
        t.row(&[
            label.into(),
            format!("{:.4}", stats.simulated_s),
            format!("{:.3}", stats.total_s),
            format!("{:.2}", lu_ops(n) / (stats.panel_s + stats.simulated_s) / 1e9),
        ]);
    }
    print!("{}", t.render());
    println!("(small N flatters neither accelerator: fill/transfer dominate — Fig 2/6.)");
}
