//! END-TO-END driver: solve a real dense linear system with the full
//! three-layer stack, proving all layers compose (DESIGN.md §6):
//!
//!   L1/L2  Pallas posit GEMM kernel, AOT-lowered by python to HLO
//!   L3     Rust coordinator: blocked LU, panels on host, trailing
//!          updates dispatched to the PJRT runtime executing the artifact
//!
//! The run factorizes A (N(0,1) entries), solves A x = b for the paper's
//! x_sol = 1/sqrt(N) right-hand side, reports per-phase timing, tile
//! counts, Gflops, and the Eq.(4) backward error vs binary32 — and
//! cross-checks that the accelerator path is bit-identical to native.
//!
//! ```sh
//! make artifacts && cargo run --release --example lu_solve -- [N] [LOOKAHEAD]
//! ```
//!
//! The optional second argument is the lookahead depth (default 1): the
//! trailing update's tail is put in flight on the backend while the host
//! factors the next panel. Overlap changes scheduling only, never bits —
//! the example's bit-identity cross-check runs at the same depth.

use posit_accel::coordinator::drivers::{getrf_offload_lookahead, lu_ops};
use posit_accel::coordinator::{GemmBackend, NativeBackend, PjrtBackend};
use posit_accel::experiments::matgen;
use posit_accel::lapack::{backward_error, forward_error, getrs};
use posit_accel::posit::Posit32;
use posit_accel::rng::Pcg64;
use posit_accel::runtime::Runtime;
use posit_accel::{blas, lapack};

fn main() -> anyhow::Result<()> {
    let n: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(384);
    let lookahead: usize = std::env::args()
        .nth(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or(1);
    let nb = 64;
    println!("== end-to-end posit LU solve, N={n}, nb={nb}, lookahead={lookahead} ==\n");

    // Problem data in binary64 (the paper's protocol, §5.1).
    let mut rng = Pcg64::seed(2024);
    let a64 = matgen::normal_f64(n, 1.0, &mut rng);
    let (xsol, b64) = matgen::rhs_for(&a64);

    // --- the accelerator path ----------------------------------------------
    let dir = Runtime::default_dir();
    anyhow::ensure!(
        dir.is_dir(),
        "artifacts/ missing — run `make artifacts` first"
    );
    let be = PjrtBackend::new(&dir)?;
    println!(
        "runtime: PJRT platform={}, artifact tile {}x{}x{}",
        be.runtime().platform(),
        be.tm,
        be.tk,
        be.tn
    );

    let (ap, mut bp) = matgen::cast_problem::<Posit32>(&a64, &b64);
    let mut lu = ap.clone();
    let mut ipiv = vec![0usize; n];
    let stats = getrf_offload_lookahead(n, n, &mut lu.data, n, &mut ipiv, nb, lookahead, &be)?;
    getrs(n, 1, &lu.data, n, &ipiv, &mut bp, n);

    println!("\nfactorization (posit32 via AOT Pallas GEMM on PJRT):");
    let share = |s: f64| 100.0 * s / stats.total_s.max(1e-12);
    println!(
        "  panel (host)        {:>8.3} s  ({:>5.1}% — decode-once getf2 + trsm)",
        stats.panel_s,
        share(stats.panel_s)
    );
    println!(
        "  update (accelerator){:>8.3} s  ({:>5.1}% — pack-plan trailing GEMM)",
        stats.update_s,
        share(stats.update_s)
    );
    println!("  total               {:>8.3} s", stats.total_s);
    println!(
        "  overlap             {:>8.3} s  ({:>5.1}% of the wall hidden behind host work)",
        stats.overlap_s,
        100.0 * stats.overlap_fraction()
    );
    println!("  throughput          {:>8.1} Mflops", lu_ops(n) / stats.total_s / 1e6);
    println!("  tiles dispatched    {:>8}", be.tiles_dispatched());

    // --- verification ------------------------------------------------------
    // 1. bit-exactness vs the native backend (whose trailing updates run
    //    the pack-plan pipeline: zero decodes, zero re-packs).
    let mut lu2 = ap.clone();
    let mut ipiv2 = vec![0usize; n];
    let native_stats = getrf_offload_lookahead(
        n,
        n,
        &mut lu2.data,
        n,
        &mut ipiv2,
        nb,
        lookahead,
        &NativeBackend::new(blas::default_threads()),
    )?;
    assert_eq!(lu.data, lu2.data, "PJRT and native factors differ!");
    println!("\n  [ok] accelerator factors bit-identical to native rust");
    println!(
        "  native split: panel {:.3} s ({:.1}%) / update {:.3} s ({:.1}%) / overlap {:.1}%",
        native_stats.panel_s,
        100.0 * native_stats.panel_s / native_stats.total_s.max(1e-12),
        native_stats.update_s,
        100.0 * native_stats.update_s / native_stats.total_s.max(1e-12),
        100.0 * native_stats.overlap_fraction(),
    );

    // 2. accuracy vs binary32 (Eq. 4-5).
    let (af, mut bf) = matgen::cast_problem::<f32>(&a64, &b64);
    let mut luf = af;
    let mut ipf = vec![0usize; n];
    lapack::getrf(n, n, &mut luf.data, n, &mut ipf, nb, blas::default_threads()).unwrap();
    getrs(n, 1, &luf.data, n, &ipf, &mut bf, n);

    let (ep, fp) = (backward_error(&a64, &b64, &bp), forward_error(&xsol, &bp));
    let (ef, ff) = (backward_error(&a64, &b64, &bf), forward_error(&xsol, &bf));
    println!("\naccuracy (errors computed in binary64):");
    println!("  posit32:  backward {ep:.3e}   forward {fp:.3e}");
    println!("  binary32: backward {ef:.3e}   forward {ff:.3e}");
    println!(
        "  posit advantage: {:+.2} digits (paper Fig 7: ~+0.8 at σ=1)",
        (ef / ep).log10()
    );
    Ok(())
}
