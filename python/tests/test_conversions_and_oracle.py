"""Conversion kernels, oracle self-consistency, and edge-case sweeps.

Complements test_posit_ops.py: exercises the decode/encode (f64) kernels
the Rust runtime stages data through, the PyPosit oracle's internal
invariants (so the oracle itself is cross-braced, not just trusted), and
the known-subtle boundary patterns of the format.
"""

import sys
from fractions import Fraction
from pathlib import Path

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from compile.kernels import posit_ops as P
from compile.kernels.ref import PyPosit

ORACLE = PyPosit(32, 2)
u32 = st.integers(min_value=0, max_value=2**32 - 1)


@settings(max_examples=300, deadline=None)
@given(bits=u32)
def test_decode_f64_is_exact(bits):
    got = float(np.asarray(P.posit_to_f64(jnp.uint32(bits))))
    v = ORACLE.to_value(bits)
    if v is None:
        assert got != got  # NaR -> NaN
    else:
        assert got == float(v)
        # ...and exactly: the Fraction round-trips.
        assert Fraction(got) == v


@settings(max_examples=300, deadline=None)
@given(
    v=st.one_of(
        st.floats(allow_nan=False, allow_infinity=False, width=64),
        st.floats(min_value=-4.0, max_value=4.0),
        st.sampled_from([0.0, -0.0, 1e300, -1e300, 5e-324, 2.0**120, 2.0**-120]),
    )
)
def test_encode_f64_matches_oracle(v):
    got = int(np.asarray(P.f64_to_posit(jnp.float64(v))))
    assert got == ORACLE.from_value(v)


def test_oracle_value_encode_involution():
    """from_value(to_value(bits)) == bits for a dense sample — pins the
    oracle against itself (decode and encode are written independently)."""
    rng = np.random.default_rng(5)
    for bits in list(rng.integers(0, 2**32, 3000)) + [0, 1, 2**31 - 1, 2**31 + 1]:
        bits = int(bits)
        v = ORACLE.to_value(bits)
        if v is None:
            continue
        assert ORACLE.from_value(v) == bits, hex(bits)


def test_oracle_rounding_boundaries():
    """Hand-derived boundary cases of posit stream-RNE (see the Rust and
    pytest 'minpos' discussions)."""
    # minpos + minpos = 2^-119 rounds DOWN to minpos (cut bit = e-high = 0)
    assert ORACLE.add(1, 1) == 1
    # 2^-116 + 2^-116 = 2^-115: exact encoding-space tie -> even (stays 2)
    assert ORACLE.add(2, 2) == 2
    # near 1.0: ulp = 2^-27, plain RNE ties to even
    one = 0x40000000
    assert ORACLE.from_value(Fraction(1) + Fraction(1, 2**28)) == one
    assert ORACLE.from_value(Fraction(1) + Fraction(3, 2**28)) == one + 2
    # maxpos arithmetic saturates, never NaR
    assert ORACLE.mul(0x7FFFFFFF, 0x7FFFFFFF) == 0x7FFFFFFF
    assert ORACLE.div(one, 1) == 0x7FFFFFFF  # 1/minpos = 2^120 = maxpos


@settings(max_examples=150, deadline=None)
@given(a=u32)
def test_jnp_abs_neg_consistency(a):
    neg = int(np.asarray(P.posit_neg(jnp.uint32(a))))
    ab = int(np.asarray(P.posit_abs(jnp.uint32(a))))
    if a == 0x80000000:
        assert neg == 0x80000000 and ab == 0x80000000
    else:
        assert (neg + a) % 2**32 == 0 or a == 0
        va = ORACLE.to_value(a)
        assert ORACLE.to_value(ab) == abs(va)


def test_vectorized_ops_match_scalar_loop():
    """The jnp kernels must be elementwise (no cross-lane leakage)."""
    rng = np.random.default_rng(11)
    a = rng.integers(0, 2**32, 512, dtype=np.uint32)
    b = rng.integers(0, 2**32, 512, dtype=np.uint32)
    whole = np.asarray(P.posit_add(jnp.asarray(a), jnp.asarray(b)))
    for i in [0, 1, 255, 511]:
        lane = int(np.asarray(P.posit_add(jnp.uint32(a[i]), jnp.uint32(b[i]))))
        assert whole[i] == lane


def test_small_format_oracle_agrees_with_posit8_exhaustive():
    """PyPosit at (8,2): every add against evaluating exactly + rounding
    — an independent closure check of the generic oracle machinery."""
    py8 = PyPosit(8, 2)
    for a in range(0, 256, 3):
        va = py8.to_value(a)
        for b in range(0, 256, 7):
            got = py8.add(a, b)
            if a == 0x80 or b == 0x80:
                assert got == 0x80
                continue
            want = py8.from_value(va + py8.to_value(b))
            assert got == want, (hex(a), hex(b))


@pytest.mark.parametrize("es", [0, 1, 2, 3])
def test_oracle_parametrized_es_roundtrip(es):
    py = PyPosit(12, es)
    for bits in range(0, 1 << 12):
        if bits == py.nar:
            continue
        v = py.to_value(bits)
        assert py.from_value(v) == bits, (es, hex(bits))
