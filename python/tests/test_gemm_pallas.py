"""L1/L2 GEMM correctness: Pallas kernel vs jnp reference vs scalar oracle.

Hypothesis sweeps shapes, block sizes and input magnitudes (the paper's
sigma axis); the Pallas blocking must be invisible: results bit-identical
for every (bm, bn), and equal to the sequentially-rounded scalar oracle.
"""

import sys
from pathlib import Path

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from compile.kernels.gemm_pallas import gemm_posit_pallas, gemm_posit_jnp
from compile.kernels.ref import PyPosit, gemm_ref
from compile import model

ORACLE = PyPosit(32, 2)


def rand_posits(rng, shape, sigma):
    vals = rng.normal(0, sigma, int(np.prod(shape)))
    bits = np.array([ORACLE.from_value(float(v)) for v in vals], dtype=np.uint32)
    return bits.reshape(shape)


@settings(max_examples=12, deadline=None)
@given(
    dims=st.tuples(
        st.integers(1, 3), st.integers(1, 3), st.integers(1, 12)
    ),
    blocks=st.sampled_from([(2, 2), (2, 4), (4, 2), (4, 4)]),
    sigma=st.sampled_from([1e-2, 1.0, 1e2, 1e6]),
    seed=st.integers(0, 2**31),
    update=st.booleans(),
)
def test_pallas_matches_scalar_oracle(dims, blocks, sigma, seed, update):
    bm, bn = blocks
    m, n, k = dims[0] * bm, dims[1] * bn, dims[2]
    rng = np.random.default_rng(seed)
    a = rand_posits(rng, (m, k), sigma)
    b = rand_posits(rng, (k, n), sigma)
    c = rand_posits(rng, (m, n), sigma)
    alpha, beta = (-1, 1) if update else (1, 0)
    got = np.asarray(
        gemm_posit_pallas(
            jnp.asarray(a), jnp.asarray(b), jnp.asarray(c), bm=bm, bn=bn,
            alpha=alpha, beta=beta,
        )
    )
    want = gemm_ref(
        ORACLE,
        a.flatten().tolist(),
        b.flatten().tolist(),
        m,
        n,
        k,
        ORACLE.from_value(alpha),
        ORACLE.from_value(beta) if beta else 0,
        c.flatten().tolist() if beta else None,
    )
    assert got.flatten().tolist() == want


@settings(max_examples=10, deadline=None)
@given(
    mnk=st.tuples(st.integers(4, 16), st.integers(4, 16), st.integers(1, 16)),
    sigma=st.sampled_from([1.0, 1e4]),
    seed=st.integers(0, 2**31),
)
def test_blocking_is_invisible(mnk, sigma, seed):
    """Different (bm, bn) choices must be bit-identical (same rounding
    sequence), and equal to the non-Pallas jnp reference."""
    m, n, k = mnk
    m, n = m - m % 4 + 4, n - n % 4 + 4  # multiples of 4
    rng = np.random.default_rng(seed)
    a, b = rand_posits(rng, (m, k), sigma), rand_posits(rng, (k, n), sigma)
    c = np.zeros((m, n), dtype=np.uint32)
    ja, jb, jc = jnp.asarray(a), jnp.asarray(b), jnp.asarray(c)
    ref = np.asarray(gemm_posit_jnp(ja, jb, jc, alpha=1, beta=0))
    for bm, bn in [(2, 2), (4, 4), (m, n)]:
        if m % bm or n % bn:
            continue
        got = np.asarray(gemm_posit_pallas(ja, jb, jc, bm=bm, bn=bn))
        assert np.array_equal(got, ref), (bm, bn)


def test_nar_poisons_only_its_row_col():
    m = n = k = 4
    rng = np.random.default_rng(3)
    a = rand_posits(rng, (m, k), 1.0)
    b = rand_posits(rng, (k, n), 1.0)
    a[1, 2] = 0x80000000  # NaR
    c = np.zeros((m, n), dtype=np.uint32)
    got = np.asarray(gemm_posit_pallas(jnp.asarray(a), jnp.asarray(b), jnp.asarray(c), bm=2, bn=2))
    assert all(got[1, j] == 0x80000000 for j in range(n)), "row 1 is NaR"
    assert all(got[i, j] != 0x80000000 for i in range(m) if i != 1 for j in range(n))


def test_artifact_list_is_consistent():
    names = [name for name, _, _ in model.artifacts()]
    assert len(names) == len(set(names))
    assert any("gemm_update" in n for n in names)
    assert any("ew_div" in n for n in names)


def test_artifacts_on_disk_match_manifest():
    import json

    art = Path(__file__).resolve().parents[2] / "artifacts"
    man = art / "manifest.json"
    if not man.exists():
        import pytest

        pytest.skip("run `make artifacts` first")
    manifest = json.loads(man.read_text())
    for name, meta in manifest.items():
        f = art / meta["file"]
        assert f.exists(), name
        text = f.read_text()
        assert "ENTRY" in text, f"{name} is not HLO text"
        import hashlib

        assert hashlib.sha256(text.encode()).hexdigest()[:16] == meta["sha256"], name
