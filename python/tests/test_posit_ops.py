"""L1 correctness: branchless jnp posit ops vs the scalar PyPosit oracle.

The hypothesis sweeps draw bit patterns from every regime (uniform u32
covers long regimes heavily) plus value-space draws across the paper's
magnitude ranges; every op must match the oracle bit-for-bit.
"""

import sys
from pathlib import Path

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from compile.kernels import posit_ops as P
from compile.kernels.ref import PyPosit

ORACLE = PyPosit(32, 2)

u32 = st.integers(min_value=0, max_value=2**32 - 1)
# Value-space draws spanning the paper's sigma ranges and Table 2 ranges.
values = st.one_of(
    st.floats(min_value=-2.0, max_value=2.0, allow_nan=False),
    st.floats(min_value=1e-38, max_value=1e-30),
    st.floats(min_value=1e30, max_value=1e38),
    st.floats(min_value=-1e15, max_value=-1e14),
    st.floats(allow_nan=False, allow_infinity=False, width=64),
)
value_bits = values.map(lambda v: ORACLE.from_value(float(v)))
posit_bits = st.one_of(
    u32,
    value_bits,
    st.sampled_from(
        [0x00000000, 0x80000000, 0x7FFFFFFF, 0x00000001, 0x40000000, 0xFFFFFFFF]
    ),
)


def jnp_scalar(fn, *args):
    return int(np.asarray(fn(*(jnp.uint32(a) for a in args))))


@settings(max_examples=400, deadline=None)
@given(a=posit_bits, b=posit_bits)
def test_add_matches_oracle(a, b):
    assert jnp_scalar(P.posit_add, a, b) == ORACLE.add(a, b)


@settings(max_examples=400, deadline=None)
@given(a=posit_bits, b=posit_bits)
def test_mul_matches_oracle(a, b):
    assert jnp_scalar(P.posit_mul, a, b) == ORACLE.mul(a, b)


@settings(max_examples=400, deadline=None)
@given(a=posit_bits, b=posit_bits)
def test_div_matches_oracle(a, b):
    assert jnp_scalar(P.posit_div, a, b) == ORACLE.div(a, b)


@settings(max_examples=400, deadline=None)
@given(a=posit_bits)
def test_sqrt_matches_oracle(a):
    assert jnp_scalar(P.posit_sqrt, a) == ORACLE.sqrt(a)


@settings(max_examples=300, deadline=None)
@given(a=posit_bits, b=posit_bits)
def test_algebraic_identities(a, b):
    add = lambda x, y: jnp_scalar(P.posit_add, x, y)
    mul = lambda x, y: jnp_scalar(P.posit_mul, x, y)
    assert add(a, b) == add(b, a)
    assert mul(a, b) == mul(b, a)
    # Multiplication by one is exact; NaR absorbs.
    assert mul(a, P.ONE) == (P.NAR if a == P.NAR else a)
    # x + (-x) == 0 for reals.
    if a != P.NAR:
        neg = jnp_scalar(P.posit_neg, a)
        assert add(a, neg) == 0


@settings(max_examples=200, deadline=None)
@given(v=st.floats(allow_nan=False, allow_infinity=False, width=64))
def test_f64_roundtrip_via_oracle(v):
    bits = jnp_scalar(P.f64_to_posit, jnp.float64(v)) if False else int(
        np.asarray(P.f64_to_posit(jnp.float64(v)))
    )
    assert bits == ORACLE.from_value(v)
    if bits not in (0x80000000,):
        back = float(np.asarray(P.posit_to_f64(jnp.uint32(bits))))
        # posit -> f64 is exact; re-rounding must be idempotent.
        assert ORACLE.from_value(back) == bits


def test_golden_vectors():
    """The shared cross-language contract (testdata/golden_posit32.txt):
    jnp ops must reproduce every line (Rust checks the same file)."""
    path = (
        Path(__file__).resolve().parents[2]
        / "rust"
        / "testdata"
        / "golden_posit32.txt"
    )
    ops, avs, bvs, wants = [], [], [], []
    for line in path.read_text().splitlines():
        if line.startswith("#") or not line.strip():
            continue
        op, a, b, r = line.split()
        ops.append(op)
        avs.append(int(a, 16))
        bvs.append(int(b, 16))
        wants.append(int(r, 16))
    a = jnp.asarray(np.array(avs, dtype=np.uint32))
    b = jnp.asarray(np.array(bvs, dtype=np.uint32))
    results = {
        "add": np.asarray(P.posit_add(a, b)),
        "mul": np.asarray(P.posit_mul(a, b)),
        "div": np.asarray(P.posit_div(a, b)),
        "sqrt": np.asarray(P.posit_sqrt(a)),
    }
    bad = [
        (i, ops[i], avs[i], bvs[i], int(results[ops[i]][i]), wants[i])
        for i in range(len(ops))
        if int(results[ops[i]][i]) != wants[i]
    ]
    assert not bad, f"{len(bad)} golden mismatches, first: {bad[:3]}"


def test_clz_exhaustive_edges():
    xs = np.array([0, 1, 2, 3, 0x7FFFFFFF, 0x80000000, 0xFFFFFFFF], dtype=np.uint32)
    got = np.asarray(P.clz32(jnp.asarray(xs)))
    want = [32, 31, 30, 30, 1, 0, 0]
    assert got.tolist() == want


@pytest.mark.parametrize(
    "a,b,want",
    [
        (0x80000000, 0x40000000, 0x80000000),  # NaR absorbs
        (0x40000000, 0xC0000000, 0x00000000),  # 1 + (-1) = 0
        (0x7FFFFFFF, 0x7FFFFFFF, 0x7FFFFFFF),  # maxpos saturates
        # minpos + minpos = 2^-119, whose encoding stream (regime 31 bits,
        # exponent e=01 entirely cut) rounds DOWN to minpos: round bit =
        # e's high bit = 0. SoftPosit agrees; a subtle posit quirk.
        (0x00000001, 0x00000001, 0x00000001),
        (0x38000000, 0x38000000, 0x40000000),  # 0.5 + 0.5 = 1.0
    ],
    ids=["nar", "cancel", "sat", "minpos", "half"],
)
def test_add_specials(a, b, want):
    assert jnp_scalar(P.posit_add, a, b) == want
