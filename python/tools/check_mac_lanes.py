#!/usr/bin/env python3
"""Bisimulation check of the Rust lane-parallel mac against the scalar mac.

``rust/src/posit/unpacked.rs`` claims ``mac_lanes`` is bit-identical to L
calls of the scalar ``mac``. This harness transcribes both functions'
*hot paths* into Python, bit for bit (u64 wrapping arithmetic, the same
selects, the same shared in-range rounding helper), and drives millions
of lane bundles of structurally valid planes through them:

* operands are representable Posit(32,2) planes (hidden bit set, frac
  truncated to the scale's fraction width, scale in [-120, 120]);
* accumulators are representable Q1.63 planes (low ``63 - fs`` bits
  clear), plus the ZERO accumulator and exact-cancellation setups;
* whenever either side reaches a rare path (special operands, NaR
  accumulator, out-of-range rounding) it returns a ``('slow', ...)``
  marker carrying the exact replay inputs — in Rust both sides then call
  the *same* scalar ``mac``/``round63`` slow code, so marker equality
  implies result equality (the bisimulation argument; the scalar mac
  itself was validated against the exact-rational oracle in earlier PRs
  and is pinned by in-crate tests).

Run: ``python3 python/tools/check_mac_lanes.py`` — exits nonzero on any
divergence. This is the authoring-time validation net for the lane
kernel; the in-crate ``mac_lanes_matches_scalar_mac_*`` property tests
pin the same contract against the real implementation.
"""

import random
import sys

M64 = (1 << 64) - 1
SCALE_BIAS = 128
F_ZERO = 1 << 41
F_NAR = 1 << 42
ES = 2

ZERO = ("zero",)
NAR = ("nar",)


def frac_bits_for_scale(scale):
    # Direct transcription of the Rust saturating u32 arithmetic.
    k = scale >> ES  # Python's >> on ints is arithmetic, like i32 >>
    rs = k + 2 if k >= 0 else -k + 1
    a = 31 - rs if rs <= 31 else 0  # 31u32.saturating_sub(rs)
    b = a - ES if a >= ES else 0  # .saturating_sub(ES)
    return min(b, 27)


def round63_in_range(scale, sig):
    fs = frac_bits_for_scale(scale)
    cut = 63 - fs
    kept = sig >> cut
    rnd = (sig >> (cut - 1)) & 1
    sticky = 1 if (sig & ((1 << (cut - 1)) - 1)) != 0 else 0
    m = kept + (rnd & (sticky | (kept & 1)))
    ovf = m >> (fs + 1)
    return scale + ovf, ((m >> ovf) << cut) & M64


def in_range(scale):
    return -104 <= scale <= 104


def align_and_sum(accsig, accscale, accneg, psig, psc, pneg):
    """The shared magnitude-order/align/add half of both mac paths."""
    akey = ((accscale + 256) << 28) | (accsig >> 36)
    pkey = ((psc + 256) << 28) | (psig >> 36)
    swap = pkey > akey
    hs, ls = (psig, accsig) if swap else (accsig, psig)
    hsc, lsc = (psc, accscale) if swap else (accscale, psc)
    hn, ln = (pneg, accneg) if swap else (accneg, pneg)
    d = hsc - lsc
    hi62 = hs >> 1
    lo_full = ls >> 1
    lo62 = lo_full >> d if d < 64 else 0
    smask = ((1 << d) - 1) & M64 if d < 64 else M64
    sticky = 1 if (lo_full & smask) != 0 else 0
    lo_term = (-(lo62 + sticky)) & M64 if hn != ln else (lo62 + sticky)
    s = (hi62 + lo_term) & M64
    cancel = s == 0
    sum2 = s | ((1 << 63) if cancel else 0)
    lz = 64 - sum2.bit_length()
    return hsc + 1 - lz, ((sum2 << lz) & M64) | sticky, hn, cancel


def mac(acc, a, b):
    """Scalar mac hot path; ('slow', ...) marks a rare-path exit whose
    result both Rust paths compute with the same code."""
    sp = (a | b) >> 41
    if sp != 0 or acc == NAR:
        if (sp >> 1) != 0 or acc == NAR:
            return NAR
        return acc
    af = a & 0xFFFF_FFFF
    bf = b & 0xFFFF_FFFF
    asc = ((a >> 32) & 0xFF) - SCALE_BIAS
    bsc = ((b >> 32) & 0xFF) - SCALE_BIAS
    pneg = ((a ^ b) >> 40) & 1 != 0
    prod = af * bf  # Q1.31 x Q1.31 fits 64 bits exactly
    carry = (prod >> 63) & 1
    pscale_in = asc + bsc + carry
    psig_in = (prod << (1 - carry)) & M64
    if not in_range(pscale_in):
        return ("slow", "prod", pscale_in, psig_in, acc, a, b)
    psc, psig = round63_in_range(pscale_in, psig_in)
    if acc == ZERO:
        return (psig, psc, pneg)
    accsig, accscale, accneg = acc
    sscale_in, ssig_in, hn, cancel = align_and_sum(
        accsig, accscale, accneg, psig, psc, pneg
    )
    if cancel:
        # Rust computes round63 first but discards it on cancel, so the
        # (possibly slow) rounding cannot influence the result.
        return ZERO
    if not in_range(sscale_in):
        return ("slow", "sum", sscale_in, ssig_in, acc, a, b)
    rscale, rsig = round63_in_range(sscale_in, ssig_in)
    return (rsig, rscale, hn)


def mac_lanes(accs, a, bs):
    """Lane transcription: same staged structure as the Rust mac_lanes."""
    flags = a
    for b in bs:
        flags |= b
    if (flags >> 41) != 0 or any(x == NAR for x in accs):
        return [mac(x, a, b) for x, b in zip(accs, bs)]
    L = len(bs)
    af = a & 0xFFFF_FFFF
    asc = ((a >> 32) & 0xFF) - SCALE_BIAS
    psig, psc, pneg = [0] * L, [0] * L, [False] * L
    oor = False
    for j in range(L):
        bj = bs[j]
        bf = bj & 0xFFFF_FFFF
        bsc = ((bj >> 32) & 0xFF) - SCALE_BIAS
        pneg[j] = ((a ^ bj) >> 40) & 1 != 0
        prod = af * bf
        carry = (prod >> 63) & 1
        sc = asc + bsc + carry
        oor |= not in_range(sc)
        psc[j], psig[j] = round63_in_range(
            max(-104, min(104, sc)), (prod << (1 - carry)) & M64
        )
    rsig, rscale, hneg = [0] * L, [0] * L, [False] * L
    cancel = [False] * L
    live_oor = False
    for j in range(L):
        aj = accs[j]
        accsig, accscale, accneg = (1 << 63, 0, False) if aj == ZERO else aj
        sscale_in, ssig_in, hn, cj = align_and_sum(
            accsig, accscale, accneg, psig[j], psc[j], pneg[j]
        )
        hneg[j] = hn
        cancel[j] = cj
        o = not in_range(sscale_in)
        rscale[j], rsig[j] = round63_in_range(
            max(-104, min(104, sscale_in)), ssig_in
        )
        live_oor |= o and aj != ZERO and not cj
    if oor or live_oor:
        return [mac(x, a, b) for x, b in zip(accs, bs)]
    out = []
    for j in range(L):
        z = accs[j] == ZERO
        if cancel[j] and not z:
            out.append(ZERO)
        elif z:
            out.append((psig[j], psc[j], pneg[j]))
        else:
            out.append((rsig[j], rscale[j], hneg[j]))
    return out


def rand_u32_planes(rng, specials=True):
    """A representable decoded operand (or a special, when allowed)."""
    if specials:
        r = rng.randrange(16)
        if r == 0:
            return (1 << 31) | (SCALE_BIAS << 32) | F_ZERO
        if r == 1:
            return (1 << 31) | (SCALE_BIAS << 32) | F_NAR
    scale = rng.randrange(-120, 121)
    fs = frac_bits_for_scale(scale)
    frac = (1 << 31) | ((rng.getrandbits(fs) << (31 - fs)) if fs else 0)
    neg = rng.randrange(2)
    return frac | ((scale + SCALE_BIAS) << 32) | (neg << 40)


def rand_acc_planes(rng):
    r = rng.randrange(12)
    if r == 0:
        return ZERO
    if r == 1:
        return NAR
    scale = rng.randrange(-120, 121)
    fs = frac_bits_for_scale(scale)
    sig = (1 << 63) | ((rng.getrandbits(fs) << (63 - fs)) if fs else 0)
    return (sig, scale, rng.randrange(2) == 1)


def neg_of_prod(a, b):
    """An accumulator equal to -round(a*b) (exact-cancellation setup), or
    None when the product takes a rare path."""
    p = mac(ZERO, a, b)
    if p == ZERO or p == NAR or p[0] == "slow":
        return None
    sig, scale, neg = p
    return (sig, scale, not neg)


def main():
    rng = random.Random(0xC0FFEE)
    checked = 0
    slow = 0
    for trial in range(400_000):
        L = 8 if trial % 3 else 4
        a = rand_u32_planes(rng)
        bs = [rand_u32_planes(rng) for _ in range(L)]
        accs = [rand_acc_planes(rng) for _ in range(L)]
        if trial % 5 == 0:
            # Cancellation-heavy bundle: some lanes hold -round(a*b).
            for j in range(0, L, 2):
                c = neg_of_prod(a, bs[j])
                if c is not None:
                    accs[j] = c
        got = mac_lanes(accs, a, bs)
        want = [mac(x, a, b) for x, b in zip(accs, bs)]
        if got != want:
            print(f"DIVERGENCE at trial {trial}:")
            print(f"  a    = {a:#x}")
            for j in range(L):
                print(f"  lane {j}: acc={accs[j]} b={bs[j]:#x}")
                print(f"    lanes  -> {got[j]}")
                print(f"    scalar -> {want[j]}")
            return 1
        checked += L
        slow += sum(1 for w in want if w not in (ZERO, NAR) and w[0] == "slow")
    print(
        f"ok: {checked} lanes bit-identical (scalar vs lane kernel), "
        f"{slow} rare-path replays agreed by bisimulation"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
