#!/usr/bin/env python3
"""Diff two hot_paths bench JSONs and flag regressions.

Compares a baseline and a candidate snapshot of the machine-readable
bench artifacts (``BENCH_factor.json``, ``BENCH_gemm.json``,
``BENCH_service.json`` — anything with the repo's ``{"quick": ...,
"rows": [...]}`` shape), matching rows on their identity fields (alg,
kernel, format, n, lookahead, workers, ...) and comparing the metric
fields. A change worse than the threshold (default 10%) on any gated
metric is a **regression**: it is printed and the exit code is 1, so CI
can wire this straight into a job step.

Gated metrics: ``seconds`` (lower is better) and the throughput columns
(``gflops``, ``gposit_ops_per_s``, ``jobs_per_s``, ``update_gflops`` —
higher is better). Informational columns (``panel_s``, ``update_s``,
``overlap_s``, ``mean_digits``, ...) are shown in the diff when they
moved, but never gate.

Rows present on only one side are listed (new rows are expected when a
PR adds bench coverage, e.g. the lookahead rows; vanished rows usually
mean a renamed kernel and deserve a look) but do not gate either.

Usage::

    python3 python/tools/bench_compare.py BASELINE.json CANDIDATE.json
    python3 python/tools/bench_compare.py base.json new.json --threshold 0.05

Stdlib only, like every tool in this directory.
"""

from __future__ import annotations

import argparse
import json
import sys

# Metric -> direction. +1: higher is better (throughput), -1: lower is
# better (wall time). Everything else in a row is identity or info.
GATED = {
    "seconds": -1,
    "gflops": +1,
    "gposit_ops_per_s": +1,
    "jobs_per_s": +1,
    "update_gflops": +1,
}

# Reported when changed, never gated (phase splits are schedule-dependent
# and machine-dependent; digits are gated by the bench itself).
INFO = ("panel_s", "update_s", "wait_s", "overlap_s", "simulated_s", "mean_digits")


def load_rows(path: str) -> list[dict]:
    with open(path, encoding="utf-8") as f:
        doc = json.load(f)
    rows = doc.get("rows")
    if not isinstance(rows, list):
        sys.exit(f"{path}: no 'rows' array — not a hot_paths bench JSON")
    return rows


def identity(row: dict) -> tuple:
    """Everything that names the measurement, in sorted-key order."""
    skip = set(GATED) | set(INFO)
    return tuple(sorted((k, v) for k, v in row.items() if k not in skip))


def fmt_id(key: tuple) -> str:
    return " ".join(f"{k}={v}" for k, v in key)


def rel_change(base: float, new: float) -> float:
    if base == 0:
        return float("inf") if new != 0 else 0.0
    return (new - base) / base


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("baseline", help="baseline bench JSON")
    ap.add_argument("candidate", help="candidate bench JSON")
    ap.add_argument(
        "--threshold",
        type=float,
        default=0.10,
        help="relative regression threshold on gated metrics (default 0.10)",
    )
    ap.add_argument(
        "--show-all",
        action="store_true",
        help="print every matched row's deltas, not just regressions",
    )
    args = ap.parse_args()

    base = {identity(r): r for r in load_rows(args.baseline)}
    cand = {identity(r): r for r in load_rows(args.candidate)}

    regressions: list[str] = []
    improvements = 0
    matched = 0

    for key in sorted(base.keys() & cand.keys(), key=fmt_id):
        b, c = base[key], cand[key]
        matched += 1
        lines: list[str] = []
        worst = 0.0
        for metric, direction in GATED.items():
            bv, cv = b.get(metric), c.get(metric)
            if not isinstance(bv, (int, float)) or not isinstance(cv, (int, float)):
                continue
            change = rel_change(bv, cv)
            # Signed badness: positive means worse, whatever the direction.
            badness = change * -direction
            tag = ""
            if badness > args.threshold:
                tag = "  << REGRESSION"
                worst = max(worst, badness)
            elif badness < -args.threshold:
                improvements += 1
            lines.append(f"    {metric}: {bv:g} -> {cv:g} ({change:+.1%}){tag}")
        for metric in INFO:
            bv, cv = b.get(metric), c.get(metric)
            if isinstance(bv, (int, float)) and isinstance(cv, (int, float)) and bv != cv:
                lines.append(f"    {metric}: {bv:g} -> {cv:g} ({rel_change(bv, cv):+.1%})  [info]")
        if worst > 0:
            regressions.append(fmt_id(key))
            print(f"REGRESSION  {fmt_id(key)}")
            print("\n".join(lines))
        elif args.show_all and lines:
            print(f"ok          {fmt_id(key)}")
            print("\n".join(lines))

    for key in sorted(cand.keys() - base.keys(), key=fmt_id):
        print(f"new row     {fmt_id(key)}")
    for key in sorted(base.keys() - cand.keys(), key=fmt_id):
        print(f"VANISHED    {fmt_id(key)}")

    print(
        f"\n{matched} rows matched, {len(cand.keys() - base.keys())} new, "
        f"{len(base.keys() - cand.keys())} vanished, {improvements} metric(s) improved "
        f"past {args.threshold:.0%}, {len(regressions)} row(s) regressed."
    )
    return 1 if regressions else 0


if __name__ == "__main__":
    sys.exit(main())
