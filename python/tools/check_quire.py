#!/usr/bin/env python3
"""Exhaustive oracle validation of the quire (exact accumulator) sweep.

``rust/src/posit/quire.rs`` claims the 512-bit fixed-point accumulator
(`GQuire`, and `Quire` for Posit(32,2) — both share the limb arithmetic
and the extraction/rounding window) accumulates posit products exactly
and rounds once, correctly (RNE with posit saturation), at extraction.

This harness transcribes the Rust algorithm bit for bit —

* the generic decode (regime/exponent/fraction -> Q1.63 significand),
* product placement at quire offset ``s + 114`` with the negative-offset
  exactness shift,
* the limb accumulation, both as the mathematically equal big-int mod
  2^512 *and* as a literal little-endian ``[u64; 8]`` limb transcription
  with ripple carry/borrow (cross-checked against each other on every
  operation, so a carry bug across the limb boundary cannot hide),
* the 64-bit extraction window + sticky sweep of ``limbs_round``,
* the generic encoder's RNE + saturation,

— and checks it against an *independent* exact big-rational oracle
(``Fraction`` sums rounded once by PyPosit, the repo's third-opinion
posit implementation) on:

* ALL 256 x 256 Posit(8,2) ``add_product`` pairs,
* ALL 256 x 256 ``sub_product`` pairs,
* chained 3-term dots (every pattern appears in every position against
  a magnitude ladder, plus a large random sweep),
* NaR / zero operands and saturating extractions, explicitly.

Run: ``python3 python/tools/check_quire.py`` — exits nonzero on any
divergence. The in-crate twin is ``rust/tests/quire_exhaustive.rs``,
which pins the same contract against the real implementation with an
i128 fixed-point oracle.
"""

import random
import sys
from fractions import Fraction
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
from compile.kernels.ref import PyPosit  # noqa: E402

M64 = (1 << 64) - 1
M512 = (1 << 512) - 1


# --------------------------------------------------------------------------
# Transcription of rust/src/posit/quire.rs (generic GQuire path)
# --------------------------------------------------------------------------

def decode_q63(p, bits):
    """PositSpec::decode transcription: None for 0/NaR, else
    (neg, scale, sig) with sig Q1.63 (hidden bit at 63)."""
    bits &= p.mask
    if bits == 0 or bits == p.nar:
        return None
    neg = bool(bits >> (p.nbits - 1))
    absb = (-bits) & p.mask if neg else bits
    i = p.nbits - 2
    r0 = (absb >> i) & 1
    run = 1
    i -= 1
    while i >= 0 and (absb >> i) & 1 == r0:
        run += 1
        i -= 1
    k = run - 1 if r0 == 1 else -run
    i -= 1  # terminator (may step past the LSB)
    e = 0
    for _ in range(p.es):
        e <<= 1
        if i >= 0:
            e |= (absb >> i) & 1
            i -= 1
    nf = max(i + 1, 0)
    frac_field = absb & ((1 << nf) - 1) if nf else 0
    sig = (1 << 63) | (frac_field << (63 - nf))
    return (neg, (k << p.es) + e, sig)


def encode_rust(p, neg, scale, sig):
    """PositSpec::encode transcription: Q1.63 sig, sticky OR-ed into bit 0,
    RNE, posit saturation (never to zero)."""
    assert sig >> 63 == 1
    if scale > p.max_scale:
        mag = p.mask >> 1  # maxpos
    elif scale < -p.max_scale:
        mag = 1  # minpos
    else:
        k = scale >> p.es
        e = scale & ((1 << p.es) - 1)
        rbit, rlen = (1, k + 1) if k >= 0 else (0, -k)
        stream = 0
        for _ in range(rlen):
            stream = (stream << 1) | rbit
        stream = (stream << 1) | (1 - rbit)
        stream = (stream << p.es) | e
        stream = (stream << 63) | (sig & ((1 << 63) - 1))
        slen = rlen + 1 + p.es + 63
        keep = p.nbits - 1
        shift = slen - keep
        kept = stream >> shift
        rnd = (stream >> (shift - 1)) & 1
        sticky = stream & ((1 << (shift - 1)) - 1) != 0
        up = rnd and (sticky or kept & 1 == 1)
        mag = kept + up
        if mag >= 1 << (p.nbits - 1):
            mag = p.mask >> 1
        elif mag == 0:
            mag = 1
    return (-mag) & p.mask if neg else mag


class LimbQuire:
    """Literal transcription of the [u64; 8] limb arithmetic."""

    def __init__(self):
        self.limbs = [0] * 8

    def add_at(self, i, v):
        s = self.limbs[i] + v
        self.limbs[i] = s & M64
        carry = s >> 64
        while carry:
            i += 1
            if i == 8:
                return  # two's-complement wrap (sign crossing)
            s = self.limbs[i] + 1
            self.limbs[i] = s & M64
            carry = s >> 64

    def sub_at(self, i, v):
        s = self.limbs[i] - v
        self.limbs[i] = s & M64
        borrow = s < 0
        while borrow:
            i += 1
            if i == 8:
                return
            s = self.limbs[i] - 1
            self.limbs[i] = s & M64
            borrow = s < 0

    def add_shifted(self, v, off, negate):
        limb, sh = off // 64, off % 64
        lo = (v << sh) & M64
        mid = v >> (64 - sh) if sh else 0
        assert limb + 1 < 8 or mid == 0, "quire overflow"
        if negate:
            self.sub_at(limb, lo)
            if mid:
                self.sub_at(limb + 1, mid)
        else:
            self.add_at(limb, lo)
            if mid:
                self.add_at(limb + 1, mid)

    def value(self):
        v = 0
        for i, l in enumerate(self.limbs):
            v |= l << (64 * i)
        return v


class GQuireT:
    """Transcription of GQuire: decode -> Q2.126 product -> offset s+114."""

    def __init__(self, p):
        self.p = p
        self.acc = 0  # big-int view, two's complement mod 2^512
        self.limbs = LimbQuire()  # literal limb view, cross-checked
        self.nar = False

    def fused(self, a, b, negate):
        p = self.p
        if self.nar or (a & p.mask) == p.nar or (b & p.mask) == p.nar:
            self.nar = True
            return
        da, db = decode_q63(p, a), decode_q63(p, b)
        if da is None or db is None:
            return
        neg = (da[0] ^ db[0]) ^ negate
        prod = da[2] * db[2]  # Q2.126, exact
        s = da[1] + db[1]
        off = s + 114
        if off < 0:
            sh = -off
            assert prod & ((1 << sh) - 1) == 0, "quire product underflow"
            prod >>= sh
            off = 0
        # Big-int view: the limb carry chain mod 2^512 is big-int addition.
        if neg:
            self.acc = (self.acc - (prod << off)) & M512
        else:
            self.acc = (self.acc + (prod << off)) & M512
        # Literal limb view: split Q2.126 into two u64 adds like the Rust.
        lo, hi = prod & M64, (prod >> 64) & M64
        self.limbs.add_shifted(lo, off, neg)
        if hi:
            self.limbs.add_shifted(hi, off + 64, neg)
        assert self.limbs.value() == self.acc, "limb/bigint divergence"

    def add_product(self, a, b):
        self.fused(a, b, False)

    def sub_product(self, a, b):
        self.fused(a, b, True)

    def to_bits(self):
        p = self.p
        if self.nar:
            return p.nar
        # limbs_round transcription.
        acc = self.acc
        negative = bool(acc >> 511)
        mag = ((-acc) & M512) if negative else acc
        if mag == 0:
            return 0
        msb = mag.bit_length() - 1
        scale = msb - 240
        if msb >= 63:
            sig = mag >> (msb - 63)
            sticky = mag & ((1 << (msb - 63)) - 1) != 0
        else:
            sig = mag << (63 - msb)
            sticky = False
        return encode_rust(p, negative, scale, sig | sticky)


# --------------------------------------------------------------------------
# Independent exact-rational oracle
# --------------------------------------------------------------------------

def exact_value(p, bits):
    """Posit bit pattern -> exact Fraction (None for NaR)."""
    bits &= p.mask
    if bits == p.nar:
        return None
    if bits == 0:
        return Fraction(0)
    d = decode_q63(p, bits)
    v = Fraction(d[2], 1 << 63) * Fraction(2) ** d[1]
    return -v if d[0] else v


def oracle_dot(p, terms):
    """terms: list of (a, b, sign). Exact Fraction sum, rounded once."""
    total = Fraction(0)
    for a, b, sign in terms:
        va, vb = exact_value(p, a), exact_value(p, b)
        if va is None or vb is None:
            return p.nar
        total += sign * va * vb
    return p.from_value(total)


def quire_dot(p, terms):
    q = GQuireT(p)
    for a, b, sign in terms:
        if sign >= 0:
            q.add_product(a, b)
        else:
            q.sub_product(a, b)
    return q.to_bits()


def check(p, terms, what):
    got = quire_dot(p, terms)
    want = oracle_dot(p, terms)
    if got != want:
        print(f"FAIL {what}: terms={[(hex(a), hex(b), s) for a, b, s in terms]} "
              f"quire={got:#x} oracle={want:#x}")
        return False
    return True


def main():
    p = PyPosit(8, 2)
    bad = 0

    # --- exhaustive single products, both signs --------------------------
    for a in range(256):
        for b in range(256):
            bad += not check(p, [(a, b, +1)], "add_product")
            bad += not check(p, [(a, b, -1)], "sub_product")
        if a % 64 == 63:
            print(f"  pairs: {(a + 1) * 256 * 2} checks, {bad} failures")

    # --- chained 3-term dots ---------------------------------------------
    # Magnitude ladder spanning minpos..maxpos and both signs: every
    # pattern appears in every position against ladder pairs.
    ladder = [0x01, 0x03, 0x10, 0x38, 0x40, 0x48, 0x70, 0x7F,
              0x81, 0x90, 0xB8, 0xC0, 0xC8, 0xF0, 0xFD, 0xFF, 0x00, 0x80]
    rng = random.Random(0xC0FFEE)
    for a in range(256):
        for _ in range(6):
            l1, l2, l3, l4 = (rng.choice(ladder) for _ in range(4))
            s1, s2, s3 = (rng.choice([+1, -1]) for _ in range(3))
            bad += not check(p, [(a, l1, s1), (l2, l3, s2), (l4, a, s3)],
                             "3-term ladder")
    print(f"  ladder dots done, {bad} failures")

    # --- random 3-term dots over the full pattern space ------------------
    for _ in range(60000):
        terms = [(rng.randrange(256), rng.randrange(256),
                  rng.choice([+1, -1])) for _ in range(3)]
        bad += not check(p, terms, "3-term random")
    print(f"  random dots done, {bad} failures")

    # --- explicit NaR / zero / saturation cases --------------------------
    maxpos, minpos, nar = 0x7F, 0x01, 0x80
    cases = [
        ([(nar, 0x00, +1)], "NaR * 0"),
        ([(0x40, 0x40, +1), (nar, 0x23, -1)], "NaR mid-dot"),
        ([(0x00, maxpos, +1), (maxpos, 0x00, -1)], "zero products"),
        ([(maxpos, maxpos, +1)], "saturation high"),
        ([(maxpos, maxpos, +1), (maxpos, maxpos, +1)], "saturation x2"),
        ([(minpos, minpos, +1)], "underflow to minpos"),
        ([(minpos, minpos, -1)], "underflow to -minpos"),
        ([(0x40, 0x40, +1), (minpos, minpos, -1)], "borrow across limbs"),
        ([(maxpos, maxpos, +1), (maxpos, maxpos, -1)], "sign crossing"),
    ]
    for terms, what in cases:
        bad += not check(p, terms, what)

    # --- Posit(32,2) spot sweep (same shared limb/extract code) ----------
    p32 = PyPosit(32, 2)
    patterns = [0, 0x8000_0000, 1, 0x7FFF_FFFF, 0x4000_0000, 0xC000_0000,
                0x7FFF_FFFE, 0x0000_0002, 0xFFFF_FFFF, 0x8000_0001]
    for _ in range(4000):
        terms = []
        for _ in range(rng.randrange(1, 4)):
            pick = lambda: (rng.choice(patterns) if rng.random() < 0.3
                            else rng.getrandbits(32))
            terms.append((pick(), pick(), rng.choice([+1, -1])))
        bad += not check(p32, terms, "posit32 random")
    print(f"  posit32 spot sweep done, {bad} failures")

    if bad:
        print(f"FAILED: {bad} mismatches")
        return 1
    print("OK: quire transcription matches the exact-rational oracle "
          "on the exhaustive Posit(8,2) sweep + posit32 spot sweep")
    return 0


if __name__ == "__main__":
    sys.exit(main())
