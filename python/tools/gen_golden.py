"""Regenerate testdata/golden_posit32.txt from the PyPosit scalar oracle.

The file is the cross-language arithmetic contract: pytest checks the jnp
kernels against it and `cargo test` checks both Rust implementations
against it. Regenerate only when extending coverage (`make golden`).
"""

import sys
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
from compile.kernels.ref import PyPosit  # noqa: E402


SEED = 1234


def main():
    py = PyPosit()
    rng = np.random.default_rng(SEED)
    lines = [
        "# golden Posit(32,2) vectors: op a_hex b_hex result_hex (b=0 for sqrt)",
        "# generator: python/tools/gen_golden.py (PyPosit scalar oracle, exact "
        "rational arithmetic)",
        f"# numpy default_rng seed: {SEED}",
    ]
    specials = [
        0x00000000, 0x80000000, 0x7FFFFFFF, 0x00000001, 0x40000000,
        0xC0000000, 0xFFFFFFFF, 0x80000001, 0x3FFFFFFF, 0x40000001,
    ]
    pats = list(specials)
    for sigma in [1.0, 1e-2, 1e2, 1e6, 1e-20, 1e20]:
        pats += [py.from_value(float(v)) for v in rng.normal(0, sigma, 120)]
    pats += [int(v) for v in rng.integers(0, 2**32, 240)]
    rng.shuffle(pats)
    n = len(pats) // 2
    for i in range(n):
        a, b = int(pats[2 * i]), int(pats[2 * i + 1])
        lines.append(f"add {a:08x} {b:08x} {py.add(a, b):08x}")
        lines.append(f"mul {a:08x} {b:08x} {py.mul(a, b):08x}")
        lines.append(f"div {a:08x} {b:08x} {py.div(a, b):08x}")
        lines.append(f"sqrt {a:08x} 00000000 {py.sqrt(a):08x}")
    out = (
        Path(__file__).resolve().parents[2]
        / "rust"
        / "testdata"
        / "golden_posit32.txt"
    )
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text("\n".join(lines) + "\n")
    print(f"wrote {len(lines)} lines to {out}")


if __name__ == "__main__":
    main()
