"""Regenerate the committed golden vectors under rust/testdata/.

* golden_posit32.txt — Posit(32,2) scalar ops from the PyPosit exact
  rational oracle. The cross-language arithmetic contract: pytest checks
  the jnp kernels against it and `cargo test` checks both Rust
  implementations against it.
* golden_f32.txt — the binary32 baseline path: IEEE-754 single scalar ops
  (numpy float32, round-to-nearest-even) plus whole `gemm_update` tiles
  computed with the repo's rounding contract (ascending-k accumulation,
  one rounding per multiply and per add, then `C - t`). `cargo test`
  checks the generic `NativeBackend<f32>` against these bit-for-bit.

Regenerate only when extending coverage (`make golden`).
"""

import sys
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
from compile.kernels.ref import PyPosit  # noqa: E402


SEED = 1234

TESTDATA = Path(__file__).resolve().parents[2] / "rust" / "testdata"


def write_posit32():
    py = PyPosit()
    rng = np.random.default_rng(SEED)
    lines = [
        "# golden Posit(32,2) vectors: op a_hex b_hex result_hex (b=0 for sqrt)",
        "# generator: python/tools/gen_golden.py (PyPosit scalar oracle, exact "
        "rational arithmetic)",
        f"# numpy default_rng seed: {SEED}",
    ]
    specials = [
        0x00000000, 0x80000000, 0x7FFFFFFF, 0x00000001, 0x40000000,
        0xC0000000, 0xFFFFFFFF, 0x80000001, 0x3FFFFFFF, 0x40000001,
    ]
    pats = list(specials)
    for sigma in [1.0, 1e-2, 1e2, 1e6, 1e-20, 1e20]:
        pats += [py.from_value(float(v)) for v in rng.normal(0, sigma, 120)]
    pats += [int(v) for v in rng.integers(0, 2**32, 240)]
    rng.shuffle(pats)
    n = len(pats) // 2
    for i in range(n):
        a, b = int(pats[2 * i]), int(pats[2 * i + 1])
        lines.append(f"add {a:08x} {b:08x} {py.add(a, b):08x}")
        lines.append(f"mul {a:08x} {b:08x} {py.mul(a, b):08x}")
        lines.append(f"div {a:08x} {b:08x} {py.div(a, b):08x}")
        lines.append(f"sqrt {a:08x} 00000000 {py.sqrt(a):08x}")
    out = TESTDATA / "golden_posit32.txt"
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text("\n".join(lines) + "\n")
    print(f"wrote {len(lines)} lines to {out}")


def _f32(x):
    return np.float32(x)


def _bits(x):
    return int(np.array([np.float32(x)], dtype=np.float32).view(np.uint32)[0])


def _val(b):
    return np.array([b], dtype=np.uint32).view(np.float32)[0]


def _is_nan_bits(b):
    return (b & 0x7F800000) == 0x7F800000 and (b & 0x007FFFFF) != 0


def _gemm_update_f32(m, k, n, a, b, c):
    """`C - A·B` with the repo's rounding contract: per output element the
    dot product accumulates from zero in ascending-k order with one float32
    rounding per multiply and per add, then one rounding for `c - t`
    (`combine(-1, t, 1, c)` in rust/src/blas/gemm.rs)."""
    out = list(c)
    for j in range(n):
        for i in range(m):
            t = _f32(0.0)
            for l in range(k):
                t = _f32(t + _f32(a[i + l * m] * b[l + j * k]))
            out[i + j * m] = _f32(c[i + j * m] - t)
    return out


def write_f32():
    rng = np.random.default_rng(SEED)
    lines = [
        "# golden binary32 (IEEE-754 single, round-to-nearest-even) vectors",
        "# generator: python/tools/gen_golden.py (numpy float32 scalar oracle)",
        f"# numpy default_rng seed: {SEED}",
        '# scalar: "op a_hex b_hex result_hex" (b=0 for sqrt); vectors whose',
        "# inputs or result are NaN are skipped (NaN payloads are not portable)",
        '# gemm tiles: "gemm m k n" then rows "A ..." "B ..." "C ..." "OUT ..."',
        "# of column-major f32 words; OUT = C - A*B per the rounding contract",
        "# of rust/src/blas/gemm.rs (ascending-k, one rounding per op)",
    ]
    specials = [
        0x00000000, 0x80000000, 0x3F800000, 0xBF800000, 0x7F7FFFFF,
        0xFF7FFFFF, 0x00800000, 0x00000001, 0x80000001, 0x7F800000,
        0xFF800000, 0x3F800001, 0x34000000, 0x00400000,
    ]
    pats = list(specials)
    for sigma in [1.0, 1e-2, 1e2, 1e6, 1e-20, 1e20]:
        pats += [_bits(v) for v in rng.normal(0, sigma, 80)]
    pats += [int(v) for v in rng.integers(0, 2**32, 160) if not _is_nan_bits(int(v))]
    rng.shuffle(pats)
    n_scalar = 0
    with np.errstate(all="ignore"):
        for i in range(len(pats) // 2):
            a, b = int(pats[2 * i]), int(pats[2 * i + 1])
            av, bv = _val(a), _val(b)
            for op, r in [
                ("add", _f32(av + bv)),
                ("mul", _f32(av * bv)),
                ("div", _f32(av / bv)),
            ]:
                rb = _bits(r)
                if _is_nan_bits(rb):
                    continue
                lines.append(f"{op} {a:08x} {b:08x} {rb:08x}")
                n_scalar += 1
            rs = _bits(np.sqrt(av))
            if not _is_nan_bits(_bits(av)) and not _is_nan_bits(rs):
                lines.append(f"sqrt {a:08x} 00000000 {rs:08x}")
                n_scalar += 1
        # gemm_update tiles: odd shapes, a k=1 and an n=1 edge, and one
        # m > 128 tile crossing the blocked kernel's row-block boundary.
        shapes = [
            (1, 1, 1, 1.0),
            (5, 3, 4, 1.0),
            (8, 2, 7, 1e-3),
            (6, 4, 1, 1.0),
            (13, 5, 9, 1e4),
            (17, 8, 11, 1.0),
            (130, 3, 2, 1e-2),
        ]
        n_tiles = 0
        for m, k, n, sigma in shapes:
            a = [_f32(v) for v in rng.normal(0, sigma, m * k)]
            b = [_f32(v) for v in rng.normal(0, sigma, k * n)]
            c = [_f32(v) for v in rng.normal(0, sigma, m * n)]
            out = _gemm_update_f32(m, k, n, a, b, c)
            lines.append(f"gemm {m} {k} {n}")
            for tag, vec in [("A", a), ("B", b), ("C", c), ("OUT", out)]:
                lines.append(f"{tag} " + " ".join(f"{_bits(v):08x}" for v in vec))
            n_tiles += 1
    out_path = TESTDATA / "golden_f32.txt"
    out_path.parent.mkdir(parents=True, exist_ok=True)
    out_path.write_text("\n".join(lines) + "\n")
    print(
        f"wrote {n_scalar} scalar vectors + {n_tiles} gemm tiles "
        f"({len(lines)} lines) to {out_path}"
    )


def main():
    write_posit32()
    write_f32()


if __name__ == "__main__":
    main()
