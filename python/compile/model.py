"""L2: the JAX computation graphs exported as AOT artifacts.

Each graph is a jitted function over posit bit-pattern arrays (uint32)
calling the L1 kernels; `aot.py` lowers every (graph, shape) pair listed in
`ARTIFACTS` to HLO text for the Rust runtime. Python never runs after
`make artifacts`.

Graphs:
  * `gemm_update`  — C <- C - A@B, the trailing-matrix update the paper
    offloads in `Rgetrf`/`Rpotrf` (alpha=-1, beta=1), via the Pallas GEMM.
  * `gemm_plain`   — C <- A@B (alpha=1, beta=0), square/rect products.
    Transposed operand layouts are handled like the paper's FPGA driver:
    the host (Rust) pre-transposes, so only the NN kernel exists on the
    accelerator (§3.1).
  * `ew_add/mul/div/sqrt` — elementwise kernels (the paper's Table 2
    microbenchmarks, executed on PJRT by the Rust `op-bench` command).
  * `decode_f64` / `encode_f64` — bulk format conversion for staging.
"""

import jax
import jax.numpy as jnp

from .kernels import posit_ops as P
from .kernels.gemm_pallas import gemm_posit_pallas


def gemm_update(a, b, c, bm=64, bn=64):
    """Trailing update: C - A@B (posit bits)."""
    return gemm_posit_pallas(a, b, c, bm=bm, bn=bn, alpha=-1, beta=1)


def gemm_plain(a, b, bm=64, bn=64):
    """Plain product: A@B (posit bits)."""
    m, _ = a.shape
    _, n = b.shape
    c = jnp.zeros((m, n), jnp.uint32)
    return gemm_posit_pallas(a, b, c, bm=bm, bn=bn, alpha=1, beta=0)


def ew_add(a, b):
    return P.posit_add(a, b)


def ew_mul(a, b):
    return P.posit_mul(a, b)


def ew_div(a, b):
    return P.posit_div(a, b)


def ew_sqrt(a):
    return P.posit_sqrt(a)


def decode_f64(a):
    return P.posit_to_f64(a)


def encode_f64(v):
    return P.f64_to_posit(v)


def _u32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.uint32)


def _f64(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float64)


# Tile shapes the Rust coordinator dispatches. (m, k, n) for GEMMs: the
# k dimension is the panel width `nb` of the blocked factorizations plus
# square tiles for bulk products; see rust/src/coordinator.
GEMM_UPDATE_SHAPES = [
    (64, 64, 64),
    (128, 64, 128),
    (128, 128, 128),
    (256, 64, 256),
]
GEMM_PLAIN_SHAPES = [
    (64, 64, 64),
    (128, 128, 128),
    (256, 256, 256),
]
EW_SIZES = [65536]


def artifacts():
    """(name, jitted fn, example args) for every artifact to export."""
    out = []
    for (m, k, n) in GEMM_UPDATE_SHAPES:
        out.append(
            (
                f"gemm_update_{m}x{k}x{n}",
                lambda a, b, c: gemm_update(a, b, c),
                (_u32((m, k)), _u32((k, n)), _u32((m, n))),
            )
        )
    for (m, k, n) in GEMM_PLAIN_SHAPES:
        out.append(
            (
                f"gemm_plain_{m}x{k}x{n}",
                lambda a, b: gemm_plain(a, b),
                (_u32((m, k)), _u32((k, n))),
            )
        )
    for s in EW_SIZES:
        out.append((f"ew_add_{s}", ew_add, (_u32((s,)), _u32((s,)))))
        out.append((f"ew_mul_{s}", ew_mul, (_u32((s,)), _u32((s,)))))
        out.append((f"ew_div_{s}", ew_div, (_u32((s,)), _u32((s,)))))
        out.append((f"ew_sqrt_{s}", ew_sqrt, (_u32((s,)),)))
        out.append((f"decode_f64_{s}", decode_f64, (_u32((s,)),)))
        out.append((f"encode_f64_{s}", encode_f64, (_f64((s,)),)))
    return out
