"""Branchless Posit(32,2) arithmetic on JAX integer arrays (L1 substrate).

This is the TPU-adapted formulation of the paper's SoftPosit GPU port
(DESIGN.md §3): where the CUDA kernels decode the regime with sequential,
divergence-prone bit loops (paper §4.2), here every step is a fixed
sequence of `uint32`/`uint64` lane operations — count-leading-zeros via
bit-smearing + popcount, i.e. a software priority encoder, the same
combinational structure the paper's FPGA decoder uses (§3.1). Latency is
therefore magnitude-independent, like the FPGA and unlike the GPU.

Exactness contract: bit-identical to the Rust implementation
(`rust/src/posit/ops.rs`) and the scalar oracle (`ref.py`), one rounding
per operation (round-to-nearest-even on the encoding stream, saturation
at +-maxpos, never-round-to-zero, NaR absorbing). Cross-checked by
`python/tests/` via hypothesis sweeps and the shared golden vectors in
`testdata/`.

Everything here is build-time only: these functions are traced by
`aot.py` into HLO artifacts which the Rust runtime executes via PJRT.
"""

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

U32 = jnp.uint32
U64 = jnp.uint64
I32 = jnp.int32

# Plain ints (not jnp scalars): inside a pallas_call trace, module-level
# jnp arrays would be captured constants, which pallas rejects. NumPy's
# weak-typing promotes these against uint32 arrays without upcasting.
ZERO = 0x00000000
NAR = 0x80000000
ONE = 0x40000000
MAXPOS = 0x7FFFFFFF
MINPOS = 0x00000001

ES = 2
MAX_SCALE = 120


def _u32(x):
    return x.astype(U32)


def _u64(x):
    return x.astype(U64)


def _i32(x):
    return x.astype(I32)


def popcount32(x):
    """Population count of a uint32 array (SWAR)."""
    x = _u32(x)
    x = x - ((x >> 1) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
    x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
    return (x * jnp.uint32(0x01010101)) >> 24


def clz32(x):
    """Count leading zeros of a uint32 array (32 for x == 0).

    Bit-smear then popcount — the branchless priority encoder.
    """
    x = _u32(x)
    x = x | (x >> 1)
    x = x | (x >> 2)
    x = x | (x >> 4)
    x = x | (x >> 8)
    x = x | (x >> 16)
    return popcount32(~x)


def clz64(x):
    """Count leading zeros of a uint64 array (64 for x == 0)."""
    x = _u64(x)
    hi = _u32(x >> 32)
    lo = _u32(x & jnp.uint64(0xFFFFFFFF))
    return jnp.where(hi != 0, clz32(hi), 32 + clz32(lo))


def _shl32(x, n):
    """uint32 << n with n possibly >= 32 (yields 0)."""
    n = _u32(n)
    return jnp.where(n >= 32, jnp.uint32(0), _u32(x) << jnp.minimum(n, jnp.uint32(31)))


def _shr64(x, n):
    """uint64 >> n with n possibly >= 64 (yields 0)."""
    n = _u64(n)
    return jnp.where(n >= 64, jnp.uint64(0), _u64(x) >> jnp.minimum(n, jnp.uint64(63)))


def is_nar(bits):
    return _u32(bits) == NAR


def is_zero(bits):
    return _u32(bits) == ZERO


def _special(bits):
    return is_nar(bits) | is_zero(bits)


def decode(bits):
    """Unpack nonzero/non-NaR posits to (neg, scale, frac).

    Special inputs (0 / NaR) are substituted by 1.0 before decoding so the
    arithmetic below stays well-defined; callers mask the outputs.

    Returns: neg (bool), scale (int32, in [-120, 120]), frac (uint32
    Q1.31 with the hidden bit at bit 31).
    """
    bits = jnp.where(_special(bits), ONE, _u32(bits))
    neg = (bits >> 31) != 0
    absv = jnp.where(neg, jnp.uint32(0) - bits, bits)
    x = absv << 1
    ones_run = clz32(~x)
    zeros_run = clz32(x)
    is_ones = (x >> 31) == 1
    k = jnp.where(is_ones, _i32(ones_run) - 1, -_i32(zeros_run))
    run = jnp.where(is_ones, ones_run, zeros_run)
    body = _shl32(x, run + 1)
    e = _i32(body >> 30)
    frac = jnp.uint32(0x80000000) | ((body << 2) >> 1)
    scale = (k << ES) + e
    return neg, scale, frac


def encode(neg, scale, sig):
    """Pack (sign, scale, Q1.63 significand w/ sticky bit 0) into posit
    bits. Mirrors `pack32` in rust/src/posit/mod.rs: RNE on the encoding
    stream, clamp to maxpos, never round to zero.

    Works entirely in uint64 by compressing the 63 fraction bits to
    29 + sticky (the cut always discards >= regime+1 >= 3 payload bits,
    so compressed bits can only ever land in the sticky region).
    """
    scale = _i32(scale)
    sig = _u64(sig)
    k = scale >> ES
    e = _u64(scale & 3)
    # Regime run: k+1 ones (k >= 0) or -k zeros (k < 0), then terminator.
    kpos = k >= 0
    rs = jnp.where(kpos, _u32(k + 2), _u32(1 - k))  # run + terminator
    ones = jnp.where(kpos, _u32(k + 1), jnp.uint32(0))
    # regime bits incl. terminator, right-aligned: for k>=0: (2^(k+1)-1)<<1;
    # for k<0: 1. rs <= 32 for k <= 30.
    regime = jnp.where(
        kpos,
        (_shl64_1s(ones)) << 1,
        jnp.uint64(1),
    )
    # Payload: e(2) | frac29(29) | sticky(1) = 32 bits.
    frac63 = sig & jnp.uint64(0x7FFFFFFFFFFFFFFF)  # fraction, hidden dropped
    frac29 = frac63 >> 34
    sticky_low = (frac63 & jnp.uint64((1 << 34) - 1)) != 0
    payload = (e << 30) | (frac29 << 1) | _u64(sticky_low)
    # Full stream: regime ++ payload, width rs + 32 (<= 64). Cut to 31.
    stream = (regime << 32) | payload
    shift = _u64(rs + 1)  # (rs + 32) - 31
    kept = _u32(stream >> shift)
    rnd = _u32(stream >> (shift - 1)) & 1
    sticky = (stream & ((jnp.uint64(1) << (shift - 1)) - 1)) != 0
    up = (rnd != 0) & (sticky | ((kept & 1) == 1))
    mag = kept + _u32(up)
    # Saturation / never-to-zero, then the scale clamp.
    mag = jnp.where(mag == 0, MINPOS, mag)
    mag = jnp.where(mag >= jnp.uint32(0x80000000), MAXPOS, mag)
    mag = jnp.where(scale > MAX_SCALE, MAXPOS, mag)
    mag = jnp.where(scale < -MAX_SCALE, MINPOS, mag)
    return jnp.where(neg, jnp.uint32(0) - mag, mag)


def _shl64_1s(n):
    """(2^n - 1) as uint64 for n in [0, 32]."""
    n = _u64(n)
    return jnp.where(n >= 64, ~jnp.uint64(0), (jnp.uint64(1) << n) - 1)


def posit_mul(a, b):
    """Elementwise posit multiply, one rounding."""
    na, sa, fa = decode(a)
    nb, sb, fb = decode(b)
    neg = na != nb
    scale = sa + sb
    prod = _u64(fa) * _u64(fb)  # Q2.62
    carry = (prod >> 63) != 0
    scale = scale + _i32(carry)
    sig = jnp.where(carry, prod, prod << 1)
    out = encode(neg, scale, sig)
    out = jnp.where(is_zero(a) | is_zero(b), ZERO, out)
    out = jnp.where(is_nar(a) | is_nar(b), NAR, out)
    return out


def posit_add(a, b):
    """Elementwise posit add, one rounding. Mirrors rust `add_unpacked`
    in a 64-bit frame (hidden bit at 62, 31 guard bits)."""
    a = _u32(a)
    b = _u32(b)
    na, sa, fa = decode(a)
    nb, sb, fb = decode(b)
    # Order by magnitude: (scale, frac) lexicographic.
    a_hi = (sa > sb) | ((sa == sb) & (fa >= fb))
    hn = jnp.where(a_hi, na, nb)
    hs = jnp.where(a_hi, sa, sb)
    hf = jnp.where(a_hi, fa, fb)
    ln = jnp.where(a_hi, nb, na)
    ls = jnp.where(a_hi, sb, sa)
    lf = jnp.where(a_hi, fb, fa)
    d = _u64(_u32(hs - ls))
    hi64 = _u64(hf) << 31  # hidden at 62
    lo_full = _u64(lf) << 31
    lo64 = _shr64(lo_full, d)
    # Sticky: any bit shifted out (d >= 64 -> the whole operand).
    mask = jnp.where(
        d >= 64,
        ~jnp.uint64(0),
        (jnp.uint64(1) << jnp.minimum(d, jnp.uint64(63))) - 1,
    )
    sticky = (lo_full & mask) != 0

    same = hn == ln
    # --- same sign path ---
    ssum = hi64 + lo64  # <= Q2.62, bit 63 possible
    carry = (ssum >> 63) != 0
    s_scale = hs + _i32(carry)
    s_sig = jnp.where(carry, ssum, ssum << 1) | _u64(sticky)
    # --- opposite sign path ---
    diff = hi64 - lo64 - _u64(sticky)
    diff_safe = jnp.where(diff == 0, jnp.uint64(1), diff)  # avoid clz(0)=64
    lz = clz64(diff_safe)
    shift = _u32(lz) - 1  # bring top bit to 62
    d_scale = hs - _i32(shift)
    dnorm = diff_safe << jnp.minimum(_u64(shift), jnp.uint64(63))
    d_sig = (dnorm << 1) | _u64(sticky)

    neg = hn
    scale = jnp.where(same, s_scale, d_scale)
    sig = jnp.where(same, s_sig, d_sig)
    out = encode(neg, scale, sig)
    # Exact cancellation -> true zero.
    out = jnp.where(~same & (diff == 0) & ~sticky, ZERO, out)
    # Specials.
    out = jnp.where(is_zero(a), b, out)
    out = jnp.where(is_zero(b), jnp.where(is_zero(a), ZERO, a), out)
    out = jnp.where(a == (jnp.uint32(0) - b), ZERO, out)
    out = jnp.where(is_nar(a) | is_nar(b), NAR, out)
    return out


def posit_sub(a, b):
    return posit_add(a, posit_neg(b))


def posit_neg(a):
    a = _u32(a)
    return jnp.where(is_nar(a), NAR, jnp.uint32(0) - a)


def posit_abs(a):
    a = _u32(a)
    neg = (a >> 31) != 0
    return jnp.where(is_nar(a), NAR, jnp.where(neg, jnp.uint32(0) - a, a))


def posit_div(a, b):
    """Elementwise posit divide, one rounding. x/0 = NaR."""
    na, sa, fa = decode(a)
    nb, sb, fb = decode(b)
    neg = na != nb
    scale = sa - sb
    num = _u64(fa) << 31  # Q1.62
    den = _u64(fb)
    q = num // den  # ratio in (1/2, 2) -> q in (2^30, 2^32)
    rem = (num % den) != 0
    lt1 = (q >> 31) == 0
    scale = scale - _i32(lt1)
    sig = jnp.where(lt1, q << 33, q << 32)
    out = encode(neg, scale, sig | _u64(rem))
    out = jnp.where(is_zero(a), ZERO, out)
    out = jnp.where(is_nar(a) | is_nar(b) | is_zero(b), NAR, out)
    return out


def posit_sqrt(a):
    """Elementwise posit square root, one rounding. NaR for negatives."""
    a = _u32(a)
    neg_in = ((a >> 31) != 0) & ~is_nar(a)
    n, s, f = decode(jnp.where(neg_in, ONE, a))
    del n
    odd = (s & 1) != 0
    scale = (s - _i32(odd)) >> 1
    m = _u64(f) << (29 + _u64(odd))  # in [2^60, 2^62)
    # isqrt via float seed + integer correction (exact).
    r = jnp.sqrt(m.astype(jnp.float64)).astype(U64)
    for _ in range(3):
        r = jnp.where(r * r > m, r - 1, r)
        r = jnp.where((r + 1) * (r + 1) <= m, r + 1, r)
    inexact = r * r != m
    sig = (r << 33) | _u64(inexact)  # r in [2^30, 2^31): hidden to bit 63
    out = encode(jnp.zeros_like(odd), scale, sig)
    out = jnp.where(is_zero(a), ZERO, out)
    out = jnp.where(is_nar(a) | neg_in, NAR, out)
    return out


def _exp2i(k):
    """Exact 2^k as float64 for integer k in [-1022, 1023] (bit-cast;
    jnp.exp2 is a transcendental approximation and can be 1 ulp off)."""
    biased = (k + 1023).astype(jnp.uint64) << 52
    return jax.lax.bitcast_convert_type(biased, jnp.float64)


def posit_to_f64(bits):
    """Exact conversion to float64 (every Posit(32,2) is a binary64)."""
    bits = _u32(bits)
    neg, scale, frac = decode(bits)
    m = frac.astype(jnp.float64) * _exp2i(scale - 31)
    v = jnp.where(neg, -m, m)
    v = jnp.where(is_zero(bits), 0.0, v)
    return jnp.where(is_nar(bits), jnp.float64(jnp.nan), v)


def f64_to_posit(v):
    """Round float64 to the nearest Posit(32,2) (single rounding)."""
    v = v.astype(jnp.float64)
    b = jax.lax.bitcast_convert_type(v, jnp.uint64)
    neg = (b >> 63) != 0
    biased = _i32((b >> 52) & jnp.uint64(0x7FF))
    mant = b & jnp.uint64((1 << 52) - 1)
    is_nan_inf = biased == 0x7FF
    is_zero_v = (biased == 0) & (mant == 0)
    # Subnormals saturate to minpos; normalize enough for encode's clamp.
    is_subn = (biased == 0) & (mant != 0)
    scale = jnp.where(is_subn, -1000, biased - 1023)
    sig = jnp.where(
        is_subn,
        jnp.uint64(1) << 63,
        (jnp.uint64(1) << 63) | (mant << 11),
    )
    out = encode(neg, scale, sig)
    out = jnp.where(is_zero_v, ZERO, out)
    return jnp.where(is_nan_inf, NAR, out)
