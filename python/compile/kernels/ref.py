"""Scalar Posit(n, es) oracle in pure Python — the correctness anchor.

A third, independent implementation (after the two Rust ones and the jnp
one): scalar, loop-based, and *exact by construction* — Python's unbounded
integers let every intermediate be represented without guard/sticky
machinery, and rounding happens once on the full bit stream. If this, the
Rust engines, and the jnp kernels all agree bit-for-bit, an arithmetic bug
would have to be replicated four times independently to slip through.

Also provides `gemm_ref`, the sequentially-rounded reference GEMM the
Pallas kernel is tested against (same ascending-k contract as DESIGN.md
paragraph 7).
"""

from fractions import Fraction


class PyPosit:
    """Posit(nbits, es) scalar arithmetic on integer bit patterns."""

    def __init__(self, nbits=32, es=2):
        assert 3 <= nbits <= 64 and 0 <= es <= 4
        self.nbits = nbits
        self.es = es
        self.mask = (1 << nbits) - 1
        self.nar = 1 << (nbits - 1)
        self.maxpos = self.nar - 1
        self.minpos = 1
        self.max_scale = (nbits - 2) << es

    # ---- decode / encode -------------------------------------------------

    def decode(self, bits):
        """bits -> (neg, scale, frac_numerator, frac_bits) with
        value = (-1)^neg * 2^scale * frac_num / 2^frac_bits,
        frac_num in [2^frac_bits, 2^(frac_bits+1)). None for 0 / NaR."""
        bits &= self.mask
        if bits == 0 or bits == self.nar:
            return None
        neg = bits >> (self.nbits - 1)
        absv = ((-bits) & self.mask) if neg else bits
        # Regime: run of identical bits after the sign.
        i = self.nbits - 2
        r0 = (absv >> i) & 1
        run = 0
        while i >= 0 and ((absv >> i) & 1) == r0:
            run += 1
            i -= 1
        k = run - 1 if r0 == 1 else -run
        i -= 1  # terminator
        # Exponent (missing bits read as 0).
        e = 0
        for _ in range(self.es):
            e <<= 1
            if i >= 0:
                e |= (absv >> i) & 1
                i -= 1
        # Fraction: remaining i+1 bits.
        nf = max(i + 1, 0)
        frac_field = absv & ((1 << nf) - 1) if nf else 0
        return (bool(neg), (k << self.es) + e, (1 << nf) | frac_field, nf)

    def to_value(self, bits):
        """Exact value as a Fraction (None -> NaR)."""
        bits &= self.mask
        if bits == 0:
            return Fraction(0)
        d = self.decode(bits)
        if d is None:
            return None
        neg, scale, num, nf = d
        v = Fraction(num, 1 << nf)
        v = v * Fraction(2) ** scale
        return -v if neg else v

    def encode(self, neg, scale, num, nbits_num):
        """Round (-1)^neg * 2^scale * num/2^nbits_num (num normalized:
        2^nbits_num <= num < 2^(nbits_num+1)) to the nearest posit.
        RNE on the encoding stream; posit saturation semantics."""
        assert (num >> nbits_num) == 1, "significand must be normalized"
        if scale > self.max_scale:
            mag = self.maxpos
        elif scale < -self.max_scale:
            mag = self.minpos
        else:
            k = scale >> self.es
            e = scale & ((1 << self.es) - 1)
            if k >= 0:
                regime = ((1 << (k + 1)) - 1) << 1
                rs = k + 2
            else:
                regime = 1
                rs = -k + 1
            # Exact stream: regime | exponent | fraction (hidden dropped).
            frac = num - (1 << nbits_num)
            stream = (((regime << self.es) | e) << nbits_num) | frac
            slen = rs + self.es + nbits_num
            keep = self.nbits - 1
            shift = slen - keep
            if shift <= 0:
                mag = stream << (-shift)
            else:
                kept = stream >> shift
                rnd = (stream >> (shift - 1)) & 1
                sticky = (stream & ((1 << (shift - 1)) - 1)) != 0
                mag = kept + (rnd and (sticky or (kept & 1)))
            if mag >= (1 << (self.nbits - 1)):
                mag = self.maxpos
            elif mag == 0:
                mag = self.minpos
        return ((-mag) & self.mask) if neg else mag

    def from_value(self, v):
        """Round an exact Fraction / int / float to the nearest posit."""
        if isinstance(v, float):
            if v != v or v in (float("inf"), float("-inf")):
                return self.nar
            v = Fraction(v)  # exact
        else:
            v = Fraction(v)
        if v == 0:
            return 0
        neg = v < 0
        if neg:
            v = -v
        # Normalize: v = m * 2^scale with m in [1, 2).
        scale = v.numerator.bit_length() - v.denominator.bit_length()
        if Fraction(2) ** scale > v:
            scale -= 1
        m = v / Fraction(2) ** scale  # in [1, 2)
        # Represent m to full precision: num/2^nb with enough bits that the
        # remainder folds into a final sticky (128 bits >> any posit fs).
        nb = 128
        scaled = m * (1 << nb)
        num = scaled.numerator // scaled.denominator
        if num * scaled.denominator != scaled.numerator:
            num |= 1  # sticky
        return self.encode(neg, scale, num, nb)

    # ---- arithmetic (exact compute, round once) --------------------------

    def _binop(self, a, b, f):
        a &= self.mask
        b &= self.mask
        if a == self.nar or b == self.nar:
            return self.nar
        return f(self.to_value(a), self.to_value(b))

    def add(self, a, b):
        return self._binop(a, b, lambda x, y: self.from_value(x + y))

    def sub(self, a, b):
        return self._binop(a, b, lambda x, y: self.from_value(x - y))

    def mul(self, a, b):
        return self._binop(a, b, lambda x, y: self.from_value(x * y))

    def div(self, a, b):
        def f(x, y):
            if y == 0:
                return self.nar
            return self.from_value(x / y)

        return self._binop(a, b, f)

    def sqrt(self, a):
        a &= self.mask
        if a == self.nar or a >> (self.nbits - 1):
            return self.nar
        if a == 0:
            return 0
        v = self.to_value(a)
        # Exact-or-sticky square root of a Fraction with dyadic denominator:
        # v = p / 2^q; sqrt = isqrt(p * 2^(2t - q)) / 2^t with t large.
        p, q = v.numerator, v.denominator.bit_length() - 1
        assert v.denominator == 1 << q
        t = 200
        m = p << (2 * t - q)
        r = _isqrt(m)
        exact = r * r == m
        val = Fraction(r, 1 << t)
        if exact:
            return self.from_value(val)
        # Inexact: r is the floor; encode with an explicit sticky by
        # nudging the significand representation.
        neg = False
        scale = val.numerator.bit_length() - val.denominator.bit_length()
        if Fraction(2) ** scale > val:
            scale -= 1
        nb = 192
        scaled = val / Fraction(2) ** scale * (1 << nb)
        num = scaled.numerator // scaled.denominator
        num |= 1  # sqrt inexact -> sticky
        return self.encode(neg, scale, num, nb)

    def neg(self, a):
        a &= self.mask
        return a if a == self.nar else (-a) & self.mask


def _isqrt(n):
    import math

    return math.isqrt(n)


def gemm_ref(p, a, b, m, n, k, alpha_bits, beta_bits, c):
    """Sequentially-rounded GEMM on bit-pattern lists (row-major here for
    clarity; the tests transpose as needed). Mirrors the Rust/Pallas
    contract: t = fold_l add(t, mul(a_il, b_lj)), then
    c = add(mul(alpha, t), mul(beta, c)) with beta==0 overwriting."""
    out = [0] * (m * n)
    for i in range(m):
        for j in range(n):
            t = 0
            for l in range(k):
                t = p.add(t, p.mul(a[i * k + l], b[l * n + j]))
            left = t if alpha_bits == p.from_value(1) else p.mul(alpha_bits, t)
            if beta_bits == 0:
                out[i * n + j] = left
            else:
                cb = c[i * n + j]
                cb = cb if beta_bits == p.from_value(1) else p.mul(beta_bits, cb)
                out[i * n + j] = p.add(left, cb)
    return out
