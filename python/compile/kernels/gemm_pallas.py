"""L1: Pallas blocked GEMM kernel in Posit(32,2) arithmetic.

The paper's GPU GEMM blocks A and B into shared memory and has each thread
accumulate one C element with per-operation posit rounding (§3.2). The TPU
adaptation (DESIGN.md §3): blocks are staged through VMEM by `BlockSpec`s,
the 32-lane warp becomes the 8x128 vector unit, and the posit emulation is
the branchless integer formulation of `posit_ops` — so, like the paper's
FPGA and unlike its GPU, kernel latency does not depend on operand
magnitude.

Grid: (M/bm, N/bn); each grid cell loads an (bm, K) strip of A and a
(K, bn) strip of B (posit bit patterns, uint32), decodes them ONCE
(decode is pure), and runs the k-loop with the mandatory sequential
rounding: t = add(t, mul(a_il, b_lj)), ascending l. The decode hoist is
the kernel's main optimization: it removes ~40% of the integer ops from
the loop body without touching the rounding sequence (EXPERIMENTS.md
paragraph Perf).

VMEM estimate per cell (bm = bn = 128, K = 1024): A strip 512 KiB + B
strip 512 KiB + C tile 64 KiB plus decoded components (x3) ~ 3.2 MiB —
inside the 16 MiB VMEM budget of a modern TPU core with double buffering.
`interpret=True` everywhere: the kernel lowers to plain HLO so the PJRT
CPU client (and our Rust runtime) can execute it; a real-TPU build would
lower the same kernel through Mosaic.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import posit_ops as P


def _mul_decoded(na, sa, fa, nb, sb, fb):
    """posit multiply from pre-decoded operands -> (neg, scale, sig64)."""
    neg = na != nb
    scale = sa + sb
    prod = fa.astype(jnp.uint64) * fb.astype(jnp.uint64)  # Q2.62
    carry = (prod >> 63) != 0
    scale = scale + carry.astype(jnp.int32)
    sig = jnp.where(carry, prod, prod << 1)
    return neg, scale, sig


def _mul_encode(na, sa, fa, za, ra, nb, sb, fb, zb, rb):
    """Multiply pre-decoded operands and encode, with zero/NaR masks."""
    neg, scale, sig = _mul_decoded(na, sa, fa, nb, sb, fb)
    out = P.encode(neg, scale, sig)
    out = jnp.where(za | zb, P.ZERO, out)
    return jnp.where(ra | rb, P.NAR, out)


def gemm_kernel(a_ref, b_ref, c_ref, o_ref, *, k, alpha, beta):
    """Pallas kernel body: one (bm, bn) tile of
    C = alpha * A @ B + beta * C, posit semantics."""
    a = a_ref[...]  # (bm, k) uint32
    b = b_ref[...]  # (k, bn) uint32
    # Hoisted decode (pure, magnitude-independent).
    na, sa, fa = P.decode(a)
    za, ra = P.is_zero(a), P.is_nar(a)
    nb, sb, fb = P.decode(b)
    zb, rb = P.is_zero(b), P.is_nar(b)

    bm, bn = o_ref.shape

    def body(l, t):
        # Column l of A (bm, 1) x row l of B (1, bn), posit product...
        av = lambda x: jax.lax.dynamic_slice_in_dim(x, l, 1, axis=1)
        bv = lambda x: jax.lax.dynamic_slice_in_dim(x, l, 1, axis=0)
        prod = _mul_encode(
            av(na), av(sa), av(fa), av(za), av(ra),
            bv(nb), bv(sb), bv(fb), bv(zb), bv(rb),
        )
        # ...then the sequential posit accumulation (the rounding that
        # defines the paper's numerics — must stay ordered).
        return P.posit_add(t, prod)

    t = jax.lax.fori_loop(0, k, body, jnp.full((bm, bn), P.ZERO, jnp.uint32))
    # Combine with alpha/beta (compile-time constants: -1/1 for the
    # trailing update, 1/0 for plain product).
    if alpha == -1:
        t = P.posit_neg(t)
    elif alpha != 1:
        raise ValueError("alpha must be +-1 in the AOT kernels")
    if beta == 0:
        o_ref[...] = t
    elif beta == 1:
        o_ref[...] = P.posit_add(t, c_ref[...])
    else:
        raise ValueError("beta must be 0 or 1 in the AOT kernels")


@functools.partial(jax.jit, static_argnames=("bm", "bn", "alpha", "beta"))
def gemm_posit_pallas(a, b, c, bm=64, bn=64, alpha=1, beta=0):
    """C = alpha * A@B + beta * C on posit bit patterns (uint32).

    a: (m, k), b: (k, n), c: (m, n); m % bm == 0, n % bn == 0.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2 and c.shape == (m, n)
    assert m % bm == 0 and n % bn == 0, (m, n, bm, bn)
    kernel = functools.partial(gemm_kernel, k=k, alpha=alpha, beta=beta)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.uint32),
        interpret=True,  # CPU-executable HLO; Mosaic on real TPU
    )(a, b, c)


def gemm_posit_jnp(a, b, c, alpha=1, beta=0):
    """Non-Pallas reference with identical semantics (scan over k on the
    whole matrices). Used to validate the Pallas blocking/indexing."""
    m, k = a.shape
    _, n = b.shape

    na, sa, fa = P.decode(a)
    za, ra = P.is_zero(a), P.is_nar(a)
    nb, sb, fb = P.decode(b)
    zb, rb = P.is_zero(b), P.is_nar(b)

    def body(l, t):
        sl = lambda x: jax.lax.dynamic_slice_in_dim(x, l, 1, axis=1)
        sr = lambda x: jax.lax.dynamic_slice_in_dim(x, l, 1, axis=0)
        prod = _mul_encode(
            sl(na), sl(sa), sl(fa), sl(za), sl(ra),
            sr(nb), sr(sb), sr(fb), sr(zb), sr(rb),
        )
        return P.posit_add(t, prod)

    t = jax.lax.fori_loop(0, k, body, jnp.full((m, n), P.ZERO, jnp.uint32))
    if alpha == -1:
        t = P.posit_neg(t)
    if beta == 1:
        t = P.posit_add(t, c)
    return t
