"""AOT lowering: JAX graphs -> HLO *text* artifacts for the Rust runtime.

HLO text (not `.serialize()`): jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids which xla_extension 0.5.1 (what the published `xla` crate
binds) rejects; the text parser reassigns ids and round-trips cleanly.
See /opt/xla-example/README.md and aot_recipe.md.

Usage: python -m compile.aot --out-dir ../artifacts
Writes one `<name>.hlo.txt` per artifact plus `manifest.json` describing
shapes/dtypes so the Rust artifact registry can validate at load time.
"""

import argparse
import hashlib
import json
import os

import jax
from jax._src.lib import xla_client as xc

jax.config.update("jax_enable_x64", True)

from . import model  # noqa: E402


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="substring filter")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {}
    for name, fn, specs in model.artifacts():
        if args.only and args.only not in name:
            continue
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest[name] = {
            "file": f"{name}.hlo.txt",
            "inputs": [
                {"shape": list(s.shape), "dtype": str(s.dtype)} for s in specs
            ],
            "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
            "bytes": len(text),
        }
        print(f"  {name}: {len(text)} chars")
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"wrote {len(manifest)} artifacts to {args.out_dir}")


if __name__ == "__main__":
    main()
